//! Service observability: the snapshot a [`MineService`] reports.
//!
//! Counters answer "is the pool keeping up" (throughput, queue depth,
//! rejections), "is coalescing/caching working" (hit rate, coalesced
//! joins), and "is the pool balanced" (per-worker utilization). Latency
//! is summarized with [`Summary`] (p50/p95/p99 via `util::stats`), over a
//! sliding window of the most recent executions so a long-lived service
//! reports current behavior, not its lifetime average.
//!
//! [`MineService`]: super::pool::MineService

use std::time::Duration;

use crate::util::stats::Summary;

use super::cache::CacheStats;

/// A point-in-time snapshot of service health. All counters are
/// cumulative since start; `queue_depth` and `cache.entries` are current.
#[derive(Clone, Debug)]
pub struct ServiceMetrics {
    /// admission attempts that passed validation (includes rejected)
    pub submitted: u64,
    /// executions that produced a result
    pub completed: u64,
    /// executions that produced an error
    pub failed: u64,
    /// submissions rejected by admission control (queue full)
    pub rejected: u64,
    /// submissions that joined an identical in-flight execution
    pub coalesced: u64,
    /// tickets currently riding an in-flight job they coalesced onto.
    /// Distinct from `queue_depth`: a coalesced waiter holds no queue
    /// slot and no worker — conflating the two overstates backlog.
    pub coalesced_waiting: usize,
    pub cache: CacheStats,
    /// jobs currently waiting for a worker
    pub queue_depth: usize,
    pub uptime: Duration,
    /// submit-to-completion latency (ns) over the most recent executions;
    /// `None` before the first completion. Cache hits answer at submit
    /// time and are not executions — client-observed latency including
    /// hits is the load generator's side of the ledger.
    pub latency_ns: Option<Summary>,
    /// cumulative busy time per worker
    pub worker_busy: Vec<Duration>,
    /// live-update subscriptions currently registered
    pub subscriptions_active: usize,
    /// subscribe attempts rejected by the per-tenant cap
    pub subscriptions_rejected: u64,
    /// incremental commits pushed through [`publish`]
    ///
    /// [`publish`]: super::pool::MineService::publish
    pub updates_published: u64,
    /// updates evicted from full subscriber mailboxes (slow consumers)
    pub updates_dropped: u64,
}

impl ServiceMetrics {
    /// Completed executions per second of uptime.
    pub fn throughput_qps(&self) -> f64 {
        let secs = self.uptime.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }

    /// Fraction of uptime each worker spent executing queries.
    pub fn worker_utilization(&self) -> Vec<f64> {
        let secs = self.uptime.as_secs_f64().max(1e-9);
        self.worker_busy.iter().map(|b| b.as_secs_f64() / secs).collect()
    }

    /// One-line human summary (the service analogue of
    /// `Metrics::report`).
    pub fn report(&self) -> String {
        let lat = match &self.latency_ns {
            Some(s) => format!(
                "p50={:.2}ms p95={:.2}ms p99={:.2}ms",
                s.median / 1e6,
                s.p95 / 1e6,
                s.p99 / 1e6
            ),
            None => "no executions yet".to_string(),
        };
        format!(
            "submitted={} completed={} failed={} rejected={} coalesced={} \
             coalesced_waiting={} \
             cache_hits={} cache_misses={} evictions={} hit_rate={:.1}% \
             queue_depth={} subs={} subs_rejected={} pushed={} dropped={} \
             qps={:.1} latency[{}] util=[{}]",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            self.coalesced,
            self.coalesced_waiting,
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            self.cache.hit_rate() * 100.0,
            self.queue_depth,
            self.subscriptions_active,
            self.subscriptions_rejected,
            self.updates_published,
            self.updates_dropped,
            self.throughput_qps(),
            lat,
            self.worker_utilization()
                .iter()
                .map(|u| format!("{:.0}%", u * 100.0))
                .collect::<Vec<_>>()
                .join(" "),
        )
    }

    /// Machine-readable summary (hand-rolled: the offline crate set has no
    /// serde).
    pub fn to_json(&self) -> String {
        let (p50, p95, p99) = match &self.latency_ns {
            Some(s) => (s.median / 1e6, s.p95 / 1e6, s.p99 / 1e6),
            None => (0.0, 0.0, 0.0),
        };
        format!(
            "{{\"submitted\":{},\"completed\":{},\"failed\":{},\"rejected\":{},\
             \"coalesced\":{},\"coalesced_waiting\":{},\
             \"cache_hits\":{},\"cache_misses\":{},\
             \"cache_evictions\":{},\"cache_hit_rate\":{:.4},\"queue_depth\":{},\
             \"subscriptions_active\":{},\"subscriptions_rejected\":{},\
             \"updates_published\":{},\"updates_dropped\":{},\
             \"uptime_s\":{:.3},\"qps\":{:.2},\"latency_ms\":{{\"p50\":{:.3},\
             \"p95\":{:.3},\"p99\":{:.3}}},\"worker_utilization\":[{}]}}",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            self.coalesced,
            self.coalesced_waiting,
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            self.cache.hit_rate(),
            self.queue_depth,
            self.subscriptions_active,
            self.subscriptions_rejected,
            self.updates_published,
            self.updates_dropped,
            self.uptime.as_secs_f64(),
            self.throughput_qps(),
            p50,
            p95,
            p99,
            self.worker_utilization()
                .iter()
                .map(|u| format!("{u:.4}"))
                .collect::<Vec<_>>()
                .join(","),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot() -> ServiceMetrics {
        ServiceMetrics {
            submitted: 10,
            completed: 6,
            failed: 0,
            rejected: 1,
            coalesced: 1,
            coalesced_waiting: 2,
            cache: CacheStats { hits: 2, misses: 8, evictions: 0, entries: 6 },
            queue_depth: 0,
            uptime: Duration::from_secs(2),
            latency_ns: Summary::of_opt(&[1e6, 2e6, 3e6]),
            worker_busy: vec![Duration::from_secs(1), Duration::from_millis(500)],
            subscriptions_active: 2,
            subscriptions_rejected: 1,
            updates_published: 7,
            updates_dropped: 3,
        }
    }

    #[test]
    fn derived_rates() {
        let m = snapshot();
        assert!((m.throughput_qps() - 3.0).abs() < 1e-9);
        let util = m.worker_utilization();
        assert!((util[0] - 0.5).abs() < 1e-9 && (util[1] - 0.25).abs() < 1e-9);
        assert!((m.cache.hit_rate() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn report_and_json_carry_the_counters() {
        let m = snapshot();
        let r = m.report();
        assert!(r.contains("rejected=1") && r.contains("p99="), "{r}");
        assert!(r.contains("subs=2") && r.contains("dropped=3"), "{r}");
        assert!(r.contains("coalesced_waiting=2"), "{r}");
        let j = m.to_json();
        assert!(j.contains("\"rejected\":1") && j.contains("\"p99\":"), "{j}");
        assert!(j.contains("\"coalesced_waiting\":2"), "{j}");
        assert!(
            j.contains("\"subscriptions_active\":2") && j.contains("\"updates_dropped\":3"),
            "{j}"
        );
        // crude but effective: the JSON must be brace-balanced
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "{j}"
        );
    }
}
