//! [`SpikeLog`]: a manifest of sealed segments with crash-safe open.
//!
//! The manifest (`MANIFEST` in the log directory) is the commit point of
//! the whole layer. It is a small text file — one header line, one line
//! per sealed segment — replaced atomically (write `MANIFEST.tmp`, fsync,
//! rename) every time a segment seals. The recovery contract follows
//! directly:
//!
//! - **a segment is sealed iff the manifest lists it.** Seal order is
//!   segment-file fsync (+ directory fsync) → manifest replace, so a
//!   listed segment's bytes are durable.
//! - **open trusts the manifest, verifies the files — and is read-only.**
//!   Every listed segment must exist with a structurally valid footer
//!   matching its manifest line; any disagreement is
//!   [`MineError::Corrupt`] — sealed data that went bad must surface,
//!   not shrink silently. Open never mutates the directory, so readers
//!   can run concurrently with an active ingest (and off read-only
//!   media) without racing the writer's seal protocol.
//! - **unlisted `*.seg` files are torn tails.** A crash between segment
//!   write and manifest replace leaves one. Open *detects* them (they
//!   are reported in the [`RecoveryReport`] and can never be mined —
//!   reads go only through the manifest); attaching the single writer
//!   ([`SpikeLog::ingestor`]) *quarantines* them (renames to
//!   `<file>.quarantined`, never clobbering an earlier copy), preserving
//!   the bytes for forensics before the seal sequence reuses the name.
//! - a leftover `MANIFEST.tmp` is an un-committed replacement: the old
//!   manifest is authoritative; the writer discards the tmp at attach.

use std::path::{Path, PathBuf};

use crate::error::MineError;
use crate::events::Tick;

use super::segment::{self, Ingestor, RollPolicy, SegmentMeta};

const MANIFEST: &str = "MANIFEST";
const MANIFEST_TMP: &str = "MANIFEST.tmp";
const MANIFEST_MAGIC: &str = "EPGLOG";
const MANIFEST_VERSION: u32 = 1;
/// Suffix quarantined torn-tail segments get on recovery.
pub const QUARANTINE_SUFFIX: &str = ".quarantined";

/// What [`SpikeLog::open`] detected (open itself never mutates the
/// directory; [`SpikeLog::ingestor`] performs the quarantine).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// unlisted (torn-tail) segment files detected at open: never mined,
    /// still on disk under their original names
    pub torn_tails: Vec<String>,
    /// torn tails renamed to `<file>.quarantined` at writer attach
    pub quarantined: Vec<String>,
    /// a leftover `MANIFEST.tmp` from an interrupted seal (the old
    /// manifest is authoritative; the writer discards it at attach)
    pub stale_tmp_manifest: bool,
}

impl RecoveryReport {
    pub fn is_clean(&self) -> bool {
        self.torn_tails.is_empty() && self.quarantined.is_empty() && !self.stale_tmp_manifest
    }
}

/// A durable, append-only spike recording: an ordered list of sealed,
/// checksummed segments under one directory. Write through
/// [`SpikeLog::ingestor`]; read through the range-query API in
/// [`super::read`].
pub struct SpikeLog {
    dir: PathBuf,
    n_types: usize,
    segments: Vec<SegmentMeta>,
    recovery: RecoveryReport,
}

impl SpikeLog {
    /// Create a fresh, empty log at `dir` (created if absent). Refuses to
    /// clobber an existing log — open that instead.
    pub fn create(dir: &Path, n_types: usize) -> Result<SpikeLog, MineError> {
        if n_types == 0 {
            return Err(MineError::invalid("SpikeLog alphabet must have n_types >= 1"));
        }
        std::fs::create_dir_all(dir).map_err(|e| {
            MineError::io(format!("creating log directory {}", dir.display()), e)
        })?;
        if dir.join(MANIFEST).exists() {
            return Err(MineError::invalid(format!(
                "a spike log already exists at {} — use SpikeLog::open",
                dir.display()
            )));
        }
        let log = SpikeLog {
            dir: dir.to_path_buf(),
            n_types,
            segments: vec![],
            recovery: RecoveryReport::default(),
        };
        log.write_manifest()?;
        Ok(log)
    }

    /// Open an existing log read-only: verify every sealed segment
    /// against the manifest and *detect* crash debris without touching
    /// the directory (see the module docs for the recovery contract —
    /// the quarantine itself runs when [`SpikeLog::ingestor`] attaches).
    pub fn open(dir: &Path) -> Result<SpikeLog, MineError> {
        // Scan the directory BEFORE reading the manifest: with a writer
        // running concurrently, a segment sealed between the two steps is
        // then already listed by the (later-read) manifest and cannot be
        // misclassified as a torn tail. The reverse order would flag a
        // just-sealed segment as torn — and a later writer attach from
        // that handle would quarantine committed data. A file appearing
        // after the scan is simply not reported this open.
        let mut seg_files: Vec<String> = vec![];
        let dir_entries = std::fs::read_dir(dir).map_err(|e| {
            MineError::io(format!("scanning log directory {}", dir.display()), e)
        })?;
        for dent in dir_entries {
            let dent = dent.map_err(|e| {
                MineError::io(format!("scanning log directory {}", dir.display()), e)
            })?;
            let name = dent.file_name().to_string_lossy().into_owned();
            if name.ends_with(".seg") {
                seg_files.push(name);
            }
        }

        let manifest_path = dir.join(MANIFEST);
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            MineError::io(format!("reading log manifest {}", manifest_path.display()), e)
        })?;
        let shown = manifest_path.display().to_string();
        let (n_types, entries) = parse_manifest(&text, &shown)?;

        // An interrupted manifest replacement leaves a tmp behind; the
        // rename never happened, so MANIFEST stays authoritative. Only
        // detect it here — open is read-only, the writer cleans up.
        let mut recovery = RecoveryReport {
            stale_tmp_manifest: dir.join(MANIFEST_TMP).exists(),
            ..RecoveryReport::default()
        };

        // Verify every sealed segment's structure against its manifest
        // line — including a digest of the footer histogram, which
        // alphabet-projection pruning trusts without reading the event
        // columns. (Full data checksums are verified at read time,
        // keeping open O(segments) — see `segment::read_meta`.)
        let mut segments: Vec<SegmentMeta> = Vec::with_capacity(entries.len());
        for entry in &entries {
            let meta = segment::read_meta(&dir.join(&entry.file), entry.seq)?;
            let matches = meta.file == entry.file
                && meta.n_events == entry.n_events
                && meta.t_min == entry.t_min
                && meta.t_max == entry.t_max
                && meta.checksum == entry.checksum
                && segment::hist_fnv(&meta.hist) == entry.hist_fnv;
            if !matches {
                return Err(MineError::corrupt(
                    dir.join(&entry.file).display().to_string(),
                    "segment footer disagrees with its manifest line",
                ));
            }
            if meta.n_types != n_types {
                return Err(MineError::corrupt(
                    &shown,
                    format!(
                        "segment {} has {} types but the log header says {n_types}",
                        meta.file, meta.n_types
                    ),
                ));
            }
            if let Some(p) = segments.last() {
                if p.seq >= meta.seq || p.t_max > meta.t_min {
                    return Err(MineError::corrupt(
                        &shown,
                        format!("segments {} and {} violate seq/time ordering", p.file, meta.file),
                    ));
                }
            }
            segments.push(meta);
        }

        // Unlisted segment files were being written when a crash hit (or
        // were sealed but never committed) — either way they are not part
        // of the recording and are never mined (reads go only through the
        // manifest). Detection only; the writer quarantines at attach.
        let listed: Vec<&str> = segments.iter().map(|m| m.file.as_str()).collect();
        recovery.torn_tails =
            seg_files.into_iter().filter(|name| !listed.contains(&name.as_str())).collect();
        recovery.torn_tails.sort();

        Ok(SpikeLog { dir: dir.to_path_buf(), n_types, segments, recovery })
    }

    /// Attach the single writer. The ingestor owns the log until
    /// [`Ingestor::finish`] hands it back. Attaching asserts write
    /// exclusivity, so this is where crash debris is repaired: torn-tail
    /// segments are quarantined (renamed `<file>.quarantined`, counter-
    /// suffixed rather than clobbering an earlier copy) and a stale
    /// `MANIFEST.tmp` is discarded.
    pub fn ingestor(mut self, policy: RollPolicy) -> Result<Ingestor, MineError> {
        self.repair_for_writing()?;
        Ingestor::new(self, policy)
    }

    /// The writer-attach half of crash recovery (see [`SpikeLog::ingestor`]).
    fn repair_for_writing(&mut self) -> Result<(), MineError> {
        // Stale-handle guard: if another writer advanced the log since
        // this handle was opened, quarantining "torn" files or sealing
        // from this view would drop committed segments. Refuse instead.
        let dst = self.dir.join(MANIFEST);
        let on_disk = std::fs::read_to_string(&dst).map_err(|e| {
            MineError::io(format!("re-reading log manifest {}", dst.display()), e)
        })?;
        let (n_types, entries) = parse_manifest(&on_disk, &dst.display().to_string())?;
        let unchanged = n_types == self.n_types
            && entries.len() == self.segments.len()
            && entries.iter().zip(&self.segments).all(|(e, m)| {
                e.seq == m.seq
                    && e.file == m.file
                    && e.n_events == m.n_events
                    && e.t_min == m.t_min
                    && e.t_max == m.t_max
                    && e.checksum == m.checksum
                    && e.hist_fnv == segment::hist_fnv(&m.hist)
            });
        if !unchanged {
            return Err(MineError::invalid(format!(
                "spike log at {} changed since this handle was opened (another \
                 writer?) — reopen it before attaching a writer",
                self.dir.display()
            )));
        }

        for name in std::mem::take(&mut self.recovery.torn_tails) {
            let from = self.dir.join(&name);
            // never clobber an earlier quarantined copy of the same name
            // (seal retries reuse seq numbers): suffix a counter until
            // the destination is free
            let mut to = self.dir.join(format!("{name}{QUARANTINE_SUFFIX}"));
            let mut copy = 1;
            while to.exists() {
                to = self.dir.join(format!("{name}{QUARANTINE_SUFFIX}.{copy}"));
                copy += 1;
            }
            std::fs::rename(&from, &to).map_err(|e| {
                MineError::io(format!("quarantining torn segment {}", from.display()), e)
            })?;
            self.recovery.quarantined.push(name);
        }
        if self.recovery.stale_tmp_manifest {
            let tmp = self.dir.join(MANIFEST_TMP);
            std::fs::remove_file(&tmp).map_err(|e| {
                MineError::io(format!("removing stale {}", tmp.display()), e)
            })?;
            self.recovery.stale_tmp_manifest = false;
        }
        Ok(())
    }

    /// Re-read the manifest and fold newly sealed segments into this
    /// handle's view. Safe concurrent with the active writer (it reuses
    /// [`SpikeLog::open`]'s scan-before-manifest ordering) and strictly
    /// append-only: a log whose committed prefix changed under this
    /// handle (rewritten, truncated, or recreated) is refused rather than
    /// silently re-synced, because a tailing miner has already folded the
    /// old prefix into live state. Returns how many segments were added.
    pub fn refresh(&mut self) -> Result<usize, MineError> {
        let fresh = SpikeLog::open(&self.dir)?;
        if fresh.n_types != self.n_types {
            return Err(MineError::corrupt(
                self.dir.display().to_string(),
                format!(
                    "log alphabet changed from {} to {} types under a live reader",
                    self.n_types, fresh.n_types
                ),
            ));
        }
        let prefix_intact = fresh.segments.len() >= self.segments.len()
            && self.segments.iter().zip(&fresh.segments).all(|(old, new)| old == new);
        if !prefix_intact {
            return Err(MineError::corrupt(
                self.dir.display().to_string(),
                "sealed segments changed under a live reader — the log was \
                 rewritten or truncated; reopen it from scratch",
            ));
        }
        let added = fresh.segments.len() - self.segments.len();
        self.segments = fresh.segments;
        self.recovery = fresh.recovery;
        Ok(added)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn n_types(&self) -> usize {
        self.n_types
    }

    /// Sealed segments, seq order.
    pub fn segments(&self) -> &[SegmentMeta] {
        &self.segments
    }

    /// Total sealed events.
    pub fn len(&self) -> usize {
        self.segments.iter().map(|m| m.n_events).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// First sealed event time (None for an empty log).
    pub fn t_begin(&self) -> Option<Tick> {
        self.segments.first().map(|m| m.t_min)
    }

    /// Last sealed event time (None for an empty log).
    pub fn t_end(&self) -> Option<Tick> {
        self.segments.last().map(|m| m.t_max)
    }

    /// Crash debris the last open detected, and what the writer attach
    /// (if any) repaired.
    pub fn recovery(&self) -> &RecoveryReport {
        &self.recovery
    }

    pub(crate) fn next_seq(&self) -> u64 {
        self.segments.last().map(|m| m.seq + 1).unwrap_or(0)
    }

    /// Record a freshly written segment: append to the in-memory list and
    /// atomically replace the manifest. This is the seal commit point.
    pub(crate) fn commit_segment(&mut self, meta: SegmentMeta) -> Result<(), MineError> {
        debug_assert_eq!(meta.seq, self.next_seq());
        self.segments.push(meta);
        if let Err(e) = self.write_manifest() {
            // the segment file exists but was never committed; forget it
            // so the in-memory view matches the durable one
            self.segments.pop();
            return Err(e);
        }
        Ok(())
    }

    fn write_manifest(&self) -> Result<(), MineError> {
        use std::io::Write;
        let mut text = format!("{MANIFEST_MAGIC} {MANIFEST_VERSION} {}\n", self.n_types);
        for m in &self.segments {
            text.push_str(&format!(
                "{} {} {} {} {} {:016x} {:016x}\n",
                m.seq,
                m.file,
                m.n_events,
                m.t_min,
                m.t_max,
                m.checksum,
                segment::hist_fnv(&m.hist),
            ));
        }
        let tmp = self.dir.join(MANIFEST_TMP);
        let ctx = |op: &str, p: &Path| format!("{op} {}", p.display());
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| MineError::io(ctx("creating", &tmp), e))?;
        f.write_all(text.as_bytes()).map_err(|e| MineError::io(ctx("writing", &tmp), e))?;
        f.sync_all().map_err(|e| MineError::io(ctx("syncing", &tmp), e))?;
        drop(f);
        let dst = self.dir.join(MANIFEST);
        std::fs::rename(&tmp, &dst)
            .map_err(|e| MineError::io(ctx("replacing manifest", &dst), e))?;
        // the rename itself is a directory mutation: fsync the directory
        // or a power cut can roll the commit back after we reported it
        fsync_dir(&self.dir)
    }
}

/// fsync a directory so renames/creates inside it survive power loss —
/// the other half of every atomic-replace protocol (file fsync makes the
/// *bytes* durable; this makes the *name* durable).
pub(crate) fn fsync_dir(dir: &Path) -> Result<(), MineError> {
    let f = std::fs::File::open(dir)
        .map_err(|e| MineError::io(format!("opening directory {}", dir.display()), e))?;
    f.sync_all()
        .map_err(|e| MineError::io(format!("syncing directory {}", dir.display()), e))
}

/// One parsed manifest line: the fields the manifest persists. The full
/// histogram lives only in segment footers (open re-reads it from there
/// and checks it against `hist_fnv`).
struct ManifestEntry {
    seq: u64,
    file: String,
    n_events: usize,
    t_min: Tick,
    t_max: Tick,
    checksum: u64,
    hist_fnv: u64,
}

fn parse_manifest(text: &str, shown: &str) -> Result<(usize, Vec<ManifestEntry>), MineError> {
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| MineError::corrupt(shown, "empty manifest"))?;
    let mut h = header.split_whitespace();
    if h.next() != Some(MANIFEST_MAGIC) {
        return Err(MineError::corrupt(shown, "bad manifest magic"));
    }
    let version: u32 = h
        .next()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| MineError::corrupt(shown, "unreadable manifest version"))?;
    if version != MANIFEST_VERSION {
        return Err(MineError::corrupt(
            shown,
            format!("unsupported manifest version {version} (expected {MANIFEST_VERSION})"),
        ));
    }
    let n_types: usize = h
        .next()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .ok_or_else(|| MineError::corrupt(shown, "unreadable manifest n_types"))?;

    let mut entries = vec![];
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let bad =
            || MineError::corrupt(shown, format!("unreadable manifest line {}", i + 2));
        let mut parts = line.split_whitespace();
        let seq: u64 = parts.next().and_then(|v| v.parse().ok()).ok_or_else(bad)?;
        let file = parts.next().ok_or_else(bad)?.to_string();
        let n_events: usize = parts.next().and_then(|v| v.parse().ok()).ok_or_else(bad)?;
        let t_min: Tick = parts.next().and_then(|v| v.parse().ok()).ok_or_else(bad)?;
        let t_max: Tick = parts.next().and_then(|v| v.parse().ok()).ok_or_else(bad)?;
        let checksum = parts
            .next()
            .and_then(|v| u64::from_str_radix(v, 16).ok())
            .ok_or_else(bad)?;
        let hist_fnv = parts
            .next()
            .and_then(|v| u64::from_str_radix(v, 16).ok())
            .ok_or_else(bad)?;
        if parts.next().is_some() {
            return Err(bad());
        }
        entries.push(ManifestEntry { seq, file, n_events, t_min, t_max, checksum, hist_fnv });
    }
    Ok((n_types, entries))
}
