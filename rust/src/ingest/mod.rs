//! Durable ingest: a segmented, append-only spike-train log with crash
//! recovery, time-range queries, and replay into the mining layers.
//!
//! The paper's chip-on-chip loop (§1, §6.5) hands partitions from the
//! acquisition chip straight to the miner; everything upstream of this
//! module mines a partition and drops it. This module closes the loop
//! with *state*: the same partition feed (or any time-sorted stream)
//! lands in an on-disk recording that can be re-mined at a different
//! theta, sliced by time range or electrode subset, replayed into the
//! serving layer, or audited after a crash — the workflow of the
//! companion temporal-data-mining papers, where one recording is mined
//! under many parameter settings.
//!
//! Three pieces:
//!
//! - [`segment`] — the columnar on-disk unit: event columns plus a footer
//!   (time bounds, per-type histogram, checksum) that makes each segment
//!   self-describing and self-verifying. [`Ingestor`] buffers appends and
//!   seals segments per a [`RollPolicy`], bridging directly from the
//!   `coordinator::streaming` partition producer.
//! - [`log`] — [`SpikeLog`]: the manifest of sealed segments, replaced
//!   atomically at every seal, with crash-safe recovery (read-only open
//!   detects torn tails and never mines them; attaching the writer
//!   quarantines them; corrupt sealed data surfaces as
//!   [`MineError::Corrupt`](crate::MineError::Corrupt)).
//! - [`read`] — [`RangeQuery`]: time-range + alphabet-projection reads
//!   that use footers to prune whole segments before any I/O, and
//!   materialize a sorted [`EventStream`](crate::events::EventStream)
//!   any `Session` or `MineService` can mine. [`TailReader`] is the live
//!   counterpart: poll the manifest for newly sealed segments
//!   ([`SpikeLog::refresh`], safe concurrent with the writer) and feed
//!   them to the incremental miner in `stream/`.
//!
//! Surfaced as `epminer ingest` / `epminer log-mine` / `epminer watch`,
//! and as the `file:`/`log:` dataset schemes every mining subcommand and
//! the serve load generator accept.

pub mod log;
pub mod read;
pub mod segment;

pub use log::{RecoveryReport, SpikeLog};
pub use read::{RangeQuery, ReadStats, TailReader};
pub use segment::{Ingestor, RollPolicy, SegmentMeta};
