//! Range-addressable reads over a [`SpikeLog`]: time windows and
//! alphabet projections, pruned by segment footers.
//!
//! The reading contract mirrors the in-memory slicing the miners already
//! use: a time range selects events in `(t_from, t_to]` exactly like
//! [`EventStream::window`], and an alphabet projection keeps events whose
//! type is in the requested set *without renumbering* — episode mining
//! over a projection reports the same global electrode ids the full
//! recording would. The materialized stream is therefore byte-for-byte
//! the stream `stream.window(..)` + type filter would produce, which is
//! what makes "mine the log range" provably equivalent to "mine the
//! in-memory slice" (see `tests/ingest_log.rs`).
//!
//! Footers prune I/O before it happens: a segment whose `[t_min, t_max]`
//! misses the range, or whose histogram shows none of the projected
//! types, is skipped without reading its event columns. [`ReadStats`]
//! reports how much work pruning saved — `benches/ingest_replay.rs`
//! measures the same numbers as wall time.

use crate::error::MineError;
use crate::events::{EventStream, EventType, Tick};

use super::log::SpikeLog;
use super::segment::{self, SegmentMeta};

/// What to read: an optional time range (half-open on the left, like
/// [`EventStream::window`]) and an optional alphabet projection.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RangeQuery {
    /// keep events with `t > t_from` (None: from the beginning)
    pub t_from: Option<Tick>,
    /// keep events with `t <= t_to` (None: to the end)
    pub t_to: Option<Tick>,
    /// keep events whose type is listed (None: every type). Types keep
    /// their global ids — a projection narrows the stream, not the
    /// alphabet.
    pub alphabet: Option<Vec<EventType>>,
}

impl RangeQuery {
    /// The whole recording.
    pub fn all() -> RangeQuery {
        RangeQuery::default()
    }

    /// Restrict to the time window `(t_from, t_to]`.
    pub fn range(mut self, t_from: Tick, t_to: Tick) -> RangeQuery {
        self.t_from = Some(t_from);
        self.t_to = Some(t_to);
        self
    }

    /// Project onto the given event types (e.g. electrodes `{3, 7, 9}`).
    pub fn types(mut self, types: Vec<EventType>) -> RangeQuery {
        self.alphabet = Some(types);
        self
    }
}

/// How much a query read — and how much the footers let it skip.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReadStats {
    pub segments_total: usize,
    pub segments_read: usize,
    /// skipped because `[t_min, t_max]` misses the time range
    pub pruned_by_time: usize,
    /// skipped because the histogram has no event of any projected type
    pub pruned_by_alphabet: usize,
    /// events decoded from the segments actually read
    pub events_scanned: usize,
    /// events in the materialized result
    pub events_returned: usize,
}

impl SpikeLog {
    /// Materialize the queried slice of the recording as a sorted
    /// [`EventStream`] ready for `Session` / `MineService`. Every segment
    /// actually read is checksum-verified first; corrupt sealed data is
    /// [`MineError::Corrupt`], never a partial answer.
    pub fn read(&self, query: &RangeQuery) -> Result<(EventStream, ReadStats), MineError> {
        let n_types = self.n_types();
        let mask = match &query.alphabet {
            None => None,
            Some(types) => {
                let mut mask = vec![false; n_types];
                for &ty in types {
                    if ty < 0 || ty as usize >= n_types {
                        return Err(MineError::OutOfAlphabet { type_id: ty, n_types });
                    }
                    mask[ty as usize] = true;
                }
                Some(mask)
            }
        };
        if let (Some(from), Some(to)) = (query.t_from, query.t_to) {
            if from > to {
                return Err(MineError::invalid(format!(
                    "empty time range: t_from {from} > t_to {to}"
                )));
            }
        }

        let mut out = EventStream::new(n_types);
        let mut stats = ReadStats { segments_total: self.segments().len(), ..Default::default() };
        for meta in self.segments() {
            let miss_low = query.t_from.is_some_and(|from| meta.t_max <= from);
            let miss_high = query.t_to.is_some_and(|to| meta.t_min > to);
            if miss_low || miss_high {
                stats.pruned_by_time += 1;
                continue;
            }
            if let Some(types) = &query.alphabet {
                if !meta.touches_types(types) {
                    stats.pruned_by_alphabet += 1;
                    continue;
                }
            }
            let seg = segment::read_segment(&self.dir().join(&meta.file), meta)?;
            stats.segments_read += 1;
            stats.events_scanned += seg.len();
            // Fast path: a segment the footer proves is entirely inside
            // the time range, with no projection, copies column-wise —
            // only range-edge segments pay the per-event filter.
            let contained = query.t_from.map_or(true, |from| from < meta.t_min)
                && query.t_to.map_or(true, |to| meta.t_max <= to);
            if contained && mask.is_none() {
                out.types.extend_from_slice(&seg.types);
                out.times.extend_from_slice(&seg.times);
                continue;
            }
            for (ty, t) in seg.iter() {
                if query.t_from.is_some_and(|from| t <= from) {
                    continue;
                }
                if query.t_to.is_some_and(|to| t > to) {
                    continue;
                }
                if let Some(mask) = &mask {
                    if !mask[ty as usize] {
                        continue;
                    }
                }
                out.push(ty, t);
            }
        }
        stats.events_returned = out.len();
        Ok((out, stats))
    }

    /// The whole recording as one stream.
    pub fn read_all(&self) -> Result<(EventStream, ReadStats), MineError> {
        self.read(&RangeQuery::all())
    }

    /// The time window `(t_from, t_to]` as one stream.
    pub fn read_range(
        &self,
        t_from: Tick,
        t_to: Tick,
    ) -> Result<(EventStream, ReadStats), MineError> {
        self.read(&RangeQuery::all().range(t_from, t_to))
    }

    /// Tail the log from the start of the recording: the first
    /// [`TailReader::poll`] replays every already-sealed segment, then
    /// each subsequent poll surfaces only what sealed since. This is the
    /// live-mining feed — `stream::LogWatcher` drives an incremental
    /// miner off it, one commit per sealed segment.
    pub fn tail(self) -> TailReader {
        TailReader { log: self, cursor: 0 }
    }

    /// Tail only segments sealed *after* this call (skip history).
    pub fn tail_from_end(self) -> TailReader {
        let cursor = self.segments().len();
        TailReader { log: self, cursor }
    }
}

/// A cursor over a [`SpikeLog`]'s sealed-segment sequence. Each
/// [`TailReader::poll`] refreshes the manifest view
/// ([`SpikeLog::refresh`] — append-only, safe concurrent with the
/// writer) and materializes every newly sealed segment as a
/// checksum-verified [`EventStream`].
pub struct TailReader {
    log: SpikeLog,
    cursor: usize,
}

impl TailReader {
    /// Newly sealed segments since the last poll, in seal order. Empty
    /// when the reader is caught up.
    pub fn poll(&mut self) -> Result<Vec<(SegmentMeta, EventStream)>, MineError> {
        self.log.refresh()?;
        let mut out = vec![];
        for meta in &self.log.segments()[self.cursor..] {
            let seg = segment::read_segment(&self.log.dir().join(&meta.file), meta)?;
            out.push((meta.clone(), seg));
        }
        self.cursor = self.log.segments().len();
        Ok(out)
    }

    /// Segments already surfaced by [`TailReader::poll`].
    pub fn position(&self) -> usize {
        self.cursor
    }

    pub fn log(&self) -> &SpikeLog {
        &self.log
    }

    /// Hand the log handle back (e.g. to run range queries).
    pub fn into_log(self) -> SpikeLog {
        self.log
    }
}
