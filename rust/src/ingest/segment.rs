//! On-disk segment format and the [`Ingestor`] that seals them.
//!
//! A segment is one immutable, columnar chunk of a spike recording
//! (little-endian throughout):
//!
//! ```text
//! offset 0   magic    b"EPSG"
//!        4   version  u32 (= 1)
//!        8   n_types  u32
//!       12   n_events u64
//!       20   types    [i32; n_events]     (columnar: all types, then
//!       20+4n times   [i32; n_events]      all times — mmap-friendly)
//! footer:    t_min    i32                 (first event time)
//!            t_max    i32                 (last event time)
//!            hist     [u64; n_types]      (per-type event counts)
//!            checksum u64                 (FNV-1a over every prior byte)
//!            trailer  b"GSPE"
//! ```
//!
//! The footer makes a sealed segment self-describing: readers prune whole
//! segments on time range (`t_min`/`t_max`) or alphabet projection
//! (`hist`) without touching the event columns, and the checksum turns a
//! torn or bit-rotted file into a typed [`MineError::Corrupt`] instead of
//! a silently wrong mining answer. [`read_meta`] validates structure only
//! (magics, version, exact length) so opening a log is O(segments);
//! [`read_segment`] re-verifies the full checksum before any event
//! reaches a miner.

use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::sync::mpsc::Receiver;

use crate::coordinator::streaming::Partition;
use crate::error::MineError;
use crate::events::{EventStream, EventType, Tick};

use super::log::SpikeLog;

pub(crate) const MAGIC: &[u8; 4] = b"EPSG";
pub(crate) const TRAILER: &[u8; 4] = b"GSPE";
pub(crate) const VERSION: u32 = 1;
pub(crate) const HEADER_LEN: usize = 20;

/// Bytes after the event columns: t_min + t_max + hist + checksum + trailer.
pub(crate) fn footer_len(n_types: usize) -> usize {
    4 + 4 + 8 * n_types + 8 + 4
}

/// Exact on-disk size of a sealed segment.
pub(crate) fn segment_len(n_events: usize, n_types: usize) -> usize {
    HEADER_LEN + 8 * n_events + footer_len(n_types)
}

/// Canonical file name for a segment sequence number.
pub fn segment_file_name(seq: u64) -> String {
    format!("segment-{seq:06}.seg")
}

/// FNV-1a over a byte slice — the segment checksum. Not cryptographic;
/// it detects torn writes and bit rot, which is the failure model here
/// (adversarial tenants meet content verification at the serve layer,
/// not the storage layer).
pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-64 over a histogram's little-endian bytes. Persisted in each
/// manifest line so `SpikeLog::open` can cross-check the footer
/// histogram — the field alphabet-projection pruning trusts — without
/// re-hashing the event columns (that full checksum runs at read time).
pub(crate) fn hist_fnv(hist: &[u64]) -> u64 {
    let mut bytes = Vec::with_capacity(8 * hist.len());
    for &c in hist {
        bytes.extend_from_slice(&c.to_le_bytes());
    }
    fnv64(&bytes)
}

/// Everything the footer records about a sealed segment, plus its
/// sequence number and file name. This is the unit the manifest lists
/// and the unit range queries prune on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentMeta {
    /// position in the log (strictly increasing, gap-free after recovery)
    pub seq: u64,
    /// file name within the log directory
    pub file: String,
    pub n_types: usize,
    pub n_events: usize,
    /// first event time in the segment
    pub t_min: Tick,
    /// last event time in the segment
    pub t_max: Tick,
    /// per-type event counts (alphabet-projection pruning)
    pub hist: Vec<u64>,
    /// FNV-1a over every byte preceding the checksum field
    pub checksum: u64,
}

impl SegmentMeta {
    /// Does any event of any of `types` occur in this segment?
    pub fn touches_types(&self, types: &[EventType]) -> bool {
        types.iter().any(|&ty| {
            ty >= 0 && (ty as usize) < self.hist.len() && self.hist[ty as usize] > 0
        })
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_i32(buf: &mut Vec<u8>, v: i32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

fn get_u64(buf: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(buf[off..off + 8].try_into().unwrap())
}

fn get_i32(buf: &[u8], off: usize) -> i32 {
    i32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

/// Serialize, checksum, write, and fsync one segment. The stream must be
/// non-empty, time-sorted, and in-alphabet (the [`Ingestor`] guarantees
/// all three; this is the low-level writer under it).
pub fn write_segment(dir: &Path, seq: u64, stream: &EventStream) -> Result<SegmentMeta, MineError> {
    debug_assert!(!stream.is_empty() && stream.check_sorted());
    let file = segment_file_name(seq);
    let path = dir.join(&file);
    let n = stream.len();
    let mut buf = Vec::with_capacity(segment_len(n, stream.n_types));
    buf.extend_from_slice(MAGIC);
    put_u32(&mut buf, VERSION);
    put_u32(&mut buf, stream.n_types as u32);
    put_u64(&mut buf, n as u64);
    for &ty in &stream.types {
        put_i32(&mut buf, ty);
    }
    for &t in &stream.times {
        put_i32(&mut buf, t);
    }
    put_i32(&mut buf, stream.t_begin());
    put_i32(&mut buf, stream.t_end());
    let hist = stream.type_counts();
    for &c in &hist {
        put_u64(&mut buf, c);
    }
    let checksum = fnv64(&buf);
    put_u64(&mut buf, checksum);
    buf.extend_from_slice(TRAILER);

    let ctx = |op: &str| format!("{op} segment {}", path.display());
    let mut f = File::create(&path).map_err(|e| MineError::io(ctx("creating"), e))?;
    f.write_all(&buf).map_err(|e| MineError::io(ctx("writing"), e))?;
    // fsync file *and directory* before the manifest ever names this
    // file: sealing order is segment durable -> manifest replaced, so a
    // manifest entry implies both the bytes and the directory entry that
    // reaches them survived the crash.
    f.sync_all().map_err(|e| MineError::io(ctx("syncing"), e))?;
    super::log::fsync_dir(dir)?;

    Ok(SegmentMeta {
        seq,
        file,
        n_types: stream.n_types,
        n_events: n,
        t_min: stream.t_begin(),
        t_max: stream.t_end(),
        hist,
        checksum,
    })
}

/// Validate the 20-byte header: magic, version, n_types > 0. Returns
/// `(n_types, advertised n_events)` — the count is *not* trusted until
/// the caller checks it against the actual file length.
fn parse_header(bytes: &[u8], shown: &str) -> Result<(usize, u64), MineError> {
    debug_assert!(bytes.len() >= HEADER_LEN);
    if &bytes[0..4] != MAGIC {
        return Err(MineError::corrupt(shown, "bad segment magic"));
    }
    let version = get_u32(bytes, 4);
    if version != VERSION {
        return Err(MineError::corrupt(
            shown,
            format!("unsupported segment version {version} (expected {VERSION})"),
        ));
    }
    let n_types = get_u32(bytes, 8) as usize;
    if n_types == 0 {
        return Err(MineError::corrupt(shown, "n_types must be > 0"));
    }
    Ok((n_types, get_u64(bytes, 12)))
}

/// The length equation every intact segment satisfies; a torn tail shows
/// up right here as a mismatch.
fn check_length(
    file_len: u64,
    n_types: usize,
    n_events64: u64,
    shown: &str,
) -> Result<usize, MineError> {
    let expected = (n_events64 as u128)
        .checked_mul(8)
        .map(|b| b + (HEADER_LEN + footer_len(n_types)) as u128);
    if expected != Some(file_len as u128) {
        return Err(MineError::corrupt(
            shown,
            format!(
                "file is {file_len} bytes but the header advertises {n_events64} \
                 events over {n_types} types — torn write?"
            ),
        ));
    }
    if n_events64 == 0 {
        return Err(MineError::corrupt(shown, "segment has zero events"));
    }
    Ok(n_events64 as usize)
}

/// Parse a footer slice (exactly `footer_len(n_types)` bytes).
fn parse_footer(
    foot: &[u8],
    n_types: usize,
    shown: &str,
) -> Result<(Tick, Tick, Vec<u64>, u64), MineError> {
    debug_assert_eq!(foot.len(), footer_len(n_types));
    if &foot[foot.len() - 4..] != TRAILER {
        return Err(MineError::corrupt(shown, "bad segment trailer — torn write?"));
    }
    let t_min = get_i32(foot, 0);
    let t_max = get_i32(foot, 4);
    let hist: Vec<u64> = (0..n_types).map(|i| get_u64(foot, 8 + 8 * i)).collect();
    let checksum = get_u64(foot, 8 + 8 * n_types);
    Ok((t_min, t_max, hist, checksum))
}

fn file_name_of(shown: &str) -> String {
    Path::new(shown)
        .file_name()
        .map(|f| f.to_string_lossy().into_owned())
        .unwrap_or_else(|| shown.to_string())
}

/// Structural validation + footer read, without touching the event
/// columns or verifying the data checksum: only the fixed-size header
/// and footer are read, so opening a log is O(segments) regardless of
/// how many events they hold ([`read_segment`] verifies the checksum
/// before any event is handed to a miner). Any structural problem —
/// short file, bad magic/version, length disagreeing with the
/// advertised event count — is [`MineError::Corrupt`].
pub fn read_meta(path: &Path, seq: u64) -> Result<SegmentMeta, MineError> {
    use std::io::{Read, Seek, SeekFrom};
    let shown = path.display().to_string();
    let ctx = || format!("reading segment header/footer {shown}");
    let mut f = File::open(path).map_err(|e| MineError::io(ctx(), e))?;
    let file_len = f.metadata().map_err(|e| MineError::io(ctx(), e))?.len();
    if file_len < HEADER_LEN as u64 {
        return Err(MineError::corrupt(
            &shown,
            format!("{file_len} bytes is shorter than the {HEADER_LEN}-byte header"),
        ));
    }
    let mut header = [0u8; HEADER_LEN];
    f.read_exact(&mut header).map_err(|e| MineError::io(ctx(), e))?;
    let (n_types, n_events64) = parse_header(&header, &shown)?;
    let n_events = check_length(file_len, n_types, n_events64, &shown)?;
    let flen = footer_len(n_types);
    f.seek(SeekFrom::End(-(flen as i64))).map_err(|e| MineError::io(ctx(), e))?;
    let mut foot = vec![0u8; flen];
    f.read_exact(&mut foot).map_err(|e| MineError::io(ctx(), e))?;
    let (t_min, t_max, hist, checksum) = parse_footer(&foot, n_types, &shown)?;
    Ok(SegmentMeta {
        seq,
        file: file_name_of(&shown),
        n_types,
        n_events,
        t_min,
        t_max,
        hist,
        checksum,
    })
}

/// Whole-buffer variant of [`read_meta`], for [`read_segment`], which
/// needs the full file in memory anyway.
fn parse_meta(bytes: &[u8], shown: &str, seq: u64) -> Result<SegmentMeta, MineError> {
    if bytes.len() < HEADER_LEN {
        return Err(MineError::corrupt(
            shown,
            format!("{} bytes is shorter than the {HEADER_LEN}-byte header", bytes.len()),
        ));
    }
    let (n_types, n_events64) = parse_header(bytes, shown)?;
    let n_events = check_length(bytes.len() as u64, n_types, n_events64, shown)?;
    let foot = &bytes[HEADER_LEN + 8 * n_events..];
    let (t_min, t_max, hist, checksum) = parse_footer(foot, n_types, shown)?;
    Ok(SegmentMeta {
        seq,
        file: file_name_of(shown),
        n_types,
        n_events,
        t_min,
        t_max,
        hist,
        checksum,
    })
}

/// Read one sealed segment back, verifying the checksum and every stream
/// invariant (sorted times, in-alphabet types, footer consistent with the
/// columns) before returning it. `expect` is the manifest's view of the
/// segment; any disagreement is [`MineError::Corrupt`].
pub fn read_segment(path: &Path, expect: &SegmentMeta) -> Result<EventStream, MineError> {
    let shown = path.display().to_string();
    let bytes = std::fs::read(path)
        .map_err(|e| MineError::io(format!("reading segment {shown}"), e))?;
    let meta = parse_meta(&bytes, &shown, expect.seq)?;
    if meta != *expect {
        return Err(MineError::corrupt(
            &shown,
            "segment footer disagrees with the manifest entry that sealed it",
        ));
    }
    let data_end = bytes.len() - 8 - 4;
    let stored = get_u64(&bytes, data_end);
    let actual = fnv64(&bytes[..data_end]);
    if stored != actual {
        return Err(MineError::corrupt(
            &shown,
            format!("checksum mismatch (stored {stored:016x}, computed {actual:016x})"),
        ));
    }
    let mut stream = EventStream::new(meta.n_types);
    stream.types.reserve(meta.n_events);
    stream.times.reserve(meta.n_events);
    for i in 0..meta.n_events {
        stream.types.push(get_i32(&bytes, HEADER_LEN + 4 * i));
    }
    let times_base = HEADER_LEN + 4 * meta.n_events;
    for i in 0..meta.n_events {
        stream.times.push(get_i32(&bytes, times_base + 4 * i));
    }
    if !stream.check_sorted() {
        return Err(MineError::corrupt(&shown, "event columns are unsorted or out of alphabet"));
    }
    if stream.t_begin() != meta.t_min
        || stream.t_end() != meta.t_max
        || stream.type_counts() != meta.hist
    {
        return Err(MineError::corrupt(&shown, "footer statistics disagree with the event columns"));
    }
    Ok(stream)
}

/// When the in-memory buffer seals into a segment. Both limits apply;
/// whichever trips first rolls the segment.
#[derive(Clone, Copy, Debug)]
pub struct RollPolicy {
    /// seal once this many events are buffered
    pub max_events: usize,
    /// seal once the buffered span reaches this many ticks
    pub max_width_ticks: Tick,
}

impl Default for RollPolicy {
    fn default() -> RollPolicy {
        // ~64 KiB of event columns per segment, or a minute of recording
        // at ms ticks — small enough that range queries prune usefully,
        // large enough that footers are noise.
        RollPolicy { max_events: 8_192, max_width_ticks: 60_000 }
    }
}

impl RollPolicy {
    fn validate(&self) -> Result<(), MineError> {
        if self.max_events == 0 {
            return Err(MineError::invalid("RollPolicy::max_events must be >= 1"));
        }
        if self.max_width_ticks <= 0 {
            return Err(MineError::invalid("RollPolicy::max_width_ticks must be > 0"));
        }
        Ok(())
    }
}

/// The write half of a [`SpikeLog`]: buffers appends, seals segments per
/// the [`RollPolicy`], and commits each seal to the manifest atomically.
///
/// The ingestor *owns* the log while writing (single-writer by
/// construction); [`Ingestor::finish`] seals the remainder and hands the
/// log back for reading. Appends must be time-ordered across the whole
/// log — the invariant that makes every segment and every cross-segment
/// concatenation a valid [`EventStream`] without re-sorting.
pub struct Ingestor {
    log: SpikeLog,
    policy: RollPolicy,
    buf: EventStream,
    appended: u64,
}

impl Ingestor {
    pub(crate) fn new(log: SpikeLog, policy: RollPolicy) -> Result<Ingestor, MineError> {
        policy.validate()?;
        let n_types = log.n_types();
        Ok(Ingestor { log, policy, buf: EventStream::new(n_types), appended: 0 })
    }

    /// Smallest time the next append may carry (monotonic across sealed
    /// segments and the buffer).
    fn floor_time(&self) -> Option<Tick> {
        self.buf.times.last().copied().or(self.log.t_end())
    }

    /// Append one event. Types outside the log's alphabet are
    /// [`MineError::OutOfAlphabet`]; out-of-order times are
    /// [`MineError::InvalidConfig`] (the producer contract is a
    /// time-ordered spike feed — see `coordinator::streaming`).
    pub fn append(&mut self, ty: EventType, t: Tick) -> Result<(), MineError> {
        let n_types = self.log.n_types();
        if ty < 0 || ty as usize >= n_types {
            return Err(MineError::OutOfAlphabet { type_id: ty, n_types });
        }
        if let Some(floor) = self.floor_time() {
            if t < floor {
                return Err(MineError::invalid(format!(
                    "ingest appends must be time-ordered: event at tick {t} after \
                     tick {floor} was already recorded"
                )));
            }
        }
        self.buf.push(ty, t);
        self.appended += 1;
        self.roll_if_due()
    }

    /// Append a whole time-sorted stream (alphabet must match the log's).
    pub fn append_stream(&mut self, stream: &EventStream) -> Result<(), MineError> {
        if stream.n_types != self.log.n_types() {
            return Err(MineError::invalid(format!(
                "stream alphabet ({} types) does not match the log's ({})",
                stream.n_types,
                self.log.n_types()
            )));
        }
        for (ty, t) in stream.iter() {
            self.append(ty, t)?;
        }
        Ok(())
    }

    /// Bridge from the chip-on-chip streaming producer: drain a partition
    /// channel (until the producer hangs up) into the log. Returns the
    /// number of events ingested.
    pub fn ingest_partitions(&mut self, rx: Receiver<Partition>) -> Result<usize, MineError> {
        let mut events = 0;
        while let Ok(part) = rx.recv() {
            events += part.stream.len();
            self.append_stream(&part.stream)?;
        }
        Ok(events)
    }

    fn roll_if_due(&mut self) -> Result<(), MineError> {
        if self.buf.len() >= self.policy.max_events
            || self.buf.span() >= self.policy.max_width_ticks
        {
            self.seal()?;
        }
        Ok(())
    }

    /// Force-seal the buffered events into a segment now (no-op when the
    /// buffer is empty). Sealing is atomic at the manifest replacement: a
    /// crash before it leaves an unlisted file the next open quarantines.
    ///
    /// On failure the buffer is kept intact, so a transient error (disk
    /// momentarily full, say) is retryable — the events are not lost. A
    /// half-written segment file from the failed attempt is harmless:
    /// unlisted, it is quarantined by the next open, and a retried seal
    /// under the same seq simply rewrites it.
    pub fn seal(&mut self) -> Result<(), MineError> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let meta = write_segment(self.log.dir(), self.log.next_seq(), &self.buf)?;
        self.log.commit_segment(meta)?;
        self.buf = EventStream::new(self.log.n_types());
        Ok(())
    }

    /// Events appended so far (buffered + sealed).
    pub fn events_appended(&self) -> u64 {
        self.appended
    }

    /// Events buffered but not yet sealed.
    pub fn events_buffered(&self) -> usize {
        self.buf.len()
    }

    /// Seal the remainder and hand the log back for reading.
    pub fn finish(mut self) -> Result<SpikeLog, MineError> {
        self.seal()?;
        Ok(self.log)
    }
}
