//! Ingest throughput and range-query replay: the tentpole metrics for
//! the `ingest/` layer.
//!
//! Phase 1 measures ingest events/s, both direct (`append_stream`) and
//! through the chip-on-chip partition producer (the acquisition path) —
//! the number that says whether the durable log can keep up with an MEA
//! feed in real time.
//!
//! Phase 2 measures what segment footers buy at query time: mining a
//! narrow time window via a *cold* full-log read versus a *pruned* range
//! query that skips non-overlapping segments before any I/O. The two
//! paths must return identical results (asserted); pruning must actually
//! skip segments (asserted).
//!
//! Run: `cargo bench --bench ingest_replay [-- --smoke]`

use std::path::PathBuf;
use std::time::Instant;

use episodes_gpu::coordinator::streaming::{spawn_producer_with, ProducerConfig};
use episodes_gpu::coordinator::Strategy;
use episodes_gpu::episodes::Interval;
use episodes_gpu::events::EventStream;
use episodes_gpu::ingest::{RollPolicy, SpikeLog};
use episodes_gpu::util::benchkit::Table;
use episodes_gpu::util::cli::{exit_usage, Args};
use episodes_gpu::util::rng::Rng;
use episodes_gpu::Session;

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ingest_replay_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn synth_stream(seed: u64, events: usize, n_types: usize) -> EventStream {
    let mut rng = Rng::new(seed);
    let mut pairs = Vec::with_capacity(events);
    let mut t = 0;
    for _ in 0..events {
        t += rng.range_i32(1, 3);
        pairs.push((rng.range_i32(0, n_types as i32 - 1), t));
    }
    EventStream::from_pairs(pairs, n_types)
}

fn mine_counts(stream: EventStream, theta: u64) -> usize {
    let mut session = Session::builder()
        .stream(stream)
        .theta(theta)
        .interval(Interval::new(0, 4))
        .strategy(Strategy::CpuParallel)
        .max_level(3)
        .build()
        .unwrap_or_else(exit_usage);
    session.mine().unwrap_or_else(exit_usage).frequent.len()
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let events = args
        .get_usize("events", if smoke { 40_000 } else { 400_000 })
        .unwrap_or_else(exit_usage);
    let n_types = 12;
    let policy = RollPolicy {
        max_events: args.get_usize("segment-events", 4_096).unwrap_or_else(exit_usage),
        max_width_ticks: 1_000_000_000,
    };
    let stream = synth_stream(0x1065, events, n_types);
    println!(
        "ingest_replay: {} events over {} types, segments of {} events{}",
        stream.len(),
        n_types,
        policy.max_events,
        if smoke { " [smoke]" } else { "" },
    );

    // Phase 1a: direct ingest throughput.
    let dir_direct = scratch("direct");
    let t0 = Instant::now();
    let mut ingestor = SpikeLog::create(&dir_direct, n_types)
        .unwrap_or_else(exit_usage)
        .ingestor(policy)
        .unwrap_or_else(exit_usage);
    ingestor.append_stream(&stream).unwrap_or_else(exit_usage);
    let log = ingestor.finish().unwrap_or_else(exit_usage);
    let direct_secs = t0.elapsed().as_secs_f64();
    let n_segments = log.segments().len();
    drop(log);

    // Phase 1b: ingest through the partition producer (accelerated
    // replay; the pacing is the producer's, the disk work is ours).
    let dir_stream = scratch("streamed");
    let width = (stream.span() / 64).max(1);
    let rx = spawn_producer_with(
        stream.clone(),
        width,
        ProducerConfig { speedup: 1e9, ..Default::default() },
    )
    .unwrap_or_else(exit_usage);
    let t0 = Instant::now();
    let mut ingestor = SpikeLog::create(&dir_stream, n_types)
        .unwrap_or_else(exit_usage)
        .ingestor(policy)
        .unwrap_or_else(exit_usage);
    let streamed = ingestor.ingest_partitions(rx).unwrap_or_else(exit_usage);
    let log = ingestor.finish().unwrap_or_else(exit_usage);
    let streamed_secs = t0.elapsed().as_secs_f64();
    assert_eq!(streamed, stream.len(), "producer-fed ingest must be lossless");

    let mut table = Table::new(
        &format!("ingest throughput ({} events, {n_segments} segments)", stream.len()),
        &["path", "wall", "events/s"],
    );
    table.row(vec![
        "append_stream".into(),
        format!("{direct_secs:.3}s"),
        format!("{:.0}", stream.len() as f64 / direct_secs.max(1e-9)),
    ]);
    table.row(vec![
        "partition producer".into(),
        format!("{streamed_secs:.3}s"),
        format!("{:.0}", streamed as f64 / streamed_secs.max(1e-9)),
    ]);
    table.print();

    // Phase 2: cold full-read mining vs footer-pruned range mining over
    // a narrow window (~1/16 of the recording).
    let span = stream.span();
    let from = stream.t_begin() + span / 2;
    let to = from + span / 16;
    let theta = if smoke { 8 } else { 40 };

    let t0 = Instant::now();
    let (full, cold_stats) = log.read_all().unwrap_or_else(exit_usage);
    let cold_window = full.window(from, to);
    let cold_frequent = mine_counts(cold_window.clone(), theta);
    let cold_secs = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let (pruned_window, pruned_stats) = log.read_range(from, to).unwrap_or_else(exit_usage);
    let pruned_frequent = mine_counts(pruned_window.clone(), theta);
    let pruned_secs = t0.elapsed().as_secs_f64();

    assert_eq!(pruned_window, cold_window, "pruned range read must equal the cold slice");
    assert_eq!(pruned_frequent, cold_frequent, "range mining must not depend on the path");
    assert!(
        pruned_stats.pruned_by_time > 0,
        "footer pruning must skip segments outside ({from}, {to}]"
    );

    let mut table = Table::new(
        &format!(
            "range-query mining over ticks ({from}, {to}] — {} of {} segments read",
            pruned_stats.segments_read, pruned_stats.segments_total
        ),
        &["path", "segments read", "events scanned", "wall", "frequent"],
    );
    table.row(vec![
        "cold full read".into(),
        format!("{}", cold_stats.segments_read),
        format!("{}", cold_stats.events_scanned),
        format!("{cold_secs:.3}s"),
        format!("{cold_frequent}"),
    ]);
    table.row(vec![
        "footer-pruned".into(),
        format!("{}", pruned_stats.segments_read),
        format!("{}", pruned_stats.events_scanned),
        format!("{pruned_secs:.3}s"),
        format!("{pruned_frequent}"),
    ]);
    table.print();
    println!(
        "\npruned replay: {:.1}x less I/O, {:.1}x wall speedup vs cold full read",
        cold_stats.events_scanned as f64 / pruned_stats.events_scanned.max(1) as f64,
        cold_secs / pruned_secs.max(1e-9),
    );

    std::fs::remove_dir_all(&dir_direct).ok();
    std::fs::remove_dir_all(&dir_stream).ok();
}
