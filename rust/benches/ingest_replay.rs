//! Durable-log ingest throughput and footer-pruned replay — registered
//! as the `ingest_replay` suite in `episodes_gpu::bench`. The suite body
//! lives in `src/bench/suites/ingest_replay.rs`.
//!
//! Run: `cargo bench --bench ingest_replay
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("ingest_replay")
}
