//! Connectivity-inference fan-out (serial surrogate loop vs the batched
//! executor) and significance scoring — registered as the `connectivity`
//! suite in `episodes_gpu::bench`. The suite body lives in
//! `src/bench/suites/connectivity.rs`.
//!
//! Run: `cargo bench --bench connectivity
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("connectivity")
}
