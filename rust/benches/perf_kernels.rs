//! Isolated kernel-execution throughput per counting artifact —
//! registered as the `perf_kernels` suite in `episodes_gpu::bench`. The
//! suite body lives in `src/bench/suites/perf_kernels.rs`.
//!
//! Run: `cargo bench --bench perf_kernels
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("perf_kernels")
}
