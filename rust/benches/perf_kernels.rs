//! §Perf microbenchmarks: isolated kernel-execution throughput for the
//! counting artifacts, separated from one-time compilation.
//!
//! Reports, per (algo, N): artifact compile time, per-call wall time over
//! a full chunk, and throughput in episode-events/s (lanes × events /
//! time) — the L1 metric the perf pass optimizes (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench perf_kernels [-- --sizes 3,5 --iters 5]`

use std::time::Instant;

use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::runtime::{exec, Runtime};
use episodes_gpu::util::benchkit::Table;
use episodes_gpu::util::cli::Args;
use episodes_gpu::util::rng::Rng;

fn main() -> Result<(), episodes_gpu::MineError> {
    let args = Args::from_env();
    let iters = args.get_usize("iters", 5)?;
    let sizes: Vec<usize> = args
        .get_or("sizes", "2,3,4,5,8")
        .split(',')
        .map(|s| {
            s.parse().map_err(|_| {
                episodes_gpu::MineError::invalid(format!(
                    "bad --sizes element {s:?} (expected a comma list of integers)"
                ))
            })
        })
        .collect::<Result<_, _>>()?;

    let rt = Runtime::open_default()?;
    let mf = *rt.manifest();
    let mut rng = Rng::new(0x9E4F);

    // exactly one full chunk of events and one full batch of episodes
    let mut pairs = vec![];
    let mut t = 0;
    for _ in 0..mf.c_chunk {
        t += rng.range_i32(0, 3);
        pairs.push((rng.range_i32(0, 25), t));
    }
    let stream = EventStream::from_pairs(pairs, 26);

    let mut table = Table::new(
        "L1 kernel throughput (one full batch x one full chunk)",
        &["artifact", "compile", "run(med)", "ep-events/s", "us/event-batch"],
    );
    for &n in &sizes {
        let iv = Interval::new(5, 15);
        let eps: Vec<Episode> = (0..mf.m_episodes)
            .map(|_| {
                let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 25)).collect();
                Episode::new(types, vec![iv; n - 1])
            })
            .collect();
        for algo in ["a2", "a1"] {
            let name = format!("{algo}_n{n}");
            let t0 = Instant::now();
            rt.executable(&name)?; // compile once
            let compile = t0.elapsed();
            let mut runs = vec![];
            for _ in 0..iters {
                let t0 = Instant::now();
                let counts = if algo == "a1" {
                    exec::count_a1(&rt, &eps, &stream)?
                } else {
                    exec::count_a2(&rt, &eps, &stream)?
                };
                std::hint::black_box(counts);
                runs.push(t0.elapsed().as_secs_f64());
            }
            runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = runs[runs.len() / 2];
            let ep_events = (mf.m_episodes * mf.c_chunk) as f64;
            table.row(vec![
                name,
                format!("{:.2}s", compile.as_secs_f64()),
                format!("{:.1}ms", med * 1e3),
                format!("{:.1}M", ep_events / med / 1e6),
                format!("{:.2}", med * 1e6 / mf.c_chunk as f64),
            ]);
        }
    }
    table.print();
    Ok(())
}
