//! Episode-axis vs stream-axis CPU scaling (the tentpole metric for the
//! sharded backend).
//!
//! The workload is the regime that motivates stream sharding: *few*
//! surviving candidates over a *long* stream — exactly what late mining
//! levels look like. Episode-axis workers (`CpuParallelBackend`) can use
//! at most `episodes` threads there; stream-axis shards (`ShardedBackend`)
//! keep every core busy regardless of the candidate count. Flip
//! `--episodes` up and `--events` down to watch the advantage invert —
//! that inversion is what `HybridBackend::cpu_sharded` dispatches on.
//!
//! Run: `cargo bench --bench axis_scaling
//!        [-- --events 200000 --episodes 4 --threads 1,2,4,8]`

use episodes_gpu::backend::cpu::CpuParallelBackend;
use episodes_gpu::backend::sharded::ShardedBackend;
use episodes_gpu::backend::CountBackend;
use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::util::benchkit::{bench, fmt_ns, BenchCfg, Table};
use episodes_gpu::util::cli::{exit_usage, Args};
use episodes_gpu::util::rng::Rng;
use episodes_gpu::MineError;

fn main() {
    let args = Args::from_env();
    let n_events = args.get_usize("events", 200_000).unwrap_or_else(exit_usage);
    let n_eps = args.get_usize("episodes", 4).unwrap_or_else(exit_usage);
    let threads: Vec<usize> = args
        .get_or("threads", "1,2,4,8")
        .split(',')
        .map(|s| {
            s.parse().map_err(|_| {
                MineError::invalid(format!(
                    "bad --threads element {s:?} (expected a comma list of integers)"
                ))
            })
        })
        .collect::<Result<_, _>>()
        .unwrap_or_else(exit_usage);

    let mut rng = Rng::new(0x5A4D);
    let mut pairs = Vec::with_capacity(n_events);
    let mut t = 0;
    for _ in 0..n_events {
        t += rng.range_i32(1, 3);
        pairs.push((rng.range_i32(0, 7), t));
    }
    let stream = EventStream::from_pairs(pairs, 8);
    let iv = Interval::new(0, 6);
    let eps: Vec<Episode> = (0..n_eps as i32)
        .map(|i| Episode::new(vec![i % 8, (i + 1) % 8, (i + 2) % 8], vec![iv; 2]))
        .collect();

    let cfg = BenchCfg::default();
    let mut table = Table::new(
        &format!("axis scaling: {n_eps} episodes x {n_events} events"),
        &["threads", "episode-axis", "stream-axis", "stream/episode speedup"],
    );
    let mut baselines = (0.0, 0.0);
    for &th in &threads {
        let ep_axis = bench(&format!("episode-axis x{th}"), &cfg, || {
            let rep = CpuParallelBackend::new(th).count(&eps, &stream).unwrap();
            rep.counts.iter().sum()
        });
        let st_axis = bench(&format!("stream-axis x{th}"), &cfg, || {
            let rep = ShardedBackend::new(th).count(&eps, &stream).unwrap();
            rep.counts.iter().sum()
        });
        assert_eq!(ep_axis.last_result, st_axis.last_result, "engines disagree");
        if th == threads[0] {
            baselines = (ep_axis.summary.mean, st_axis.summary.mean);
        }
        table.row(vec![
            format!("{th}"),
            format!(
                "{} ({:.2}x)",
                fmt_ns(ep_axis.summary.mean),
                baselines.0 / ep_axis.summary.mean
            ),
            format!(
                "{} ({:.2}x)",
                fmt_ns(st_axis.summary.mean),
                baselines.1 / st_axis.summary.mean
            ),
            format!("{:.2}x", ep_axis.summary.mean / st_axis.summary.mean),
        ]);
    }
    table.print();
    println!(
        "\nepisode-axis self-speedup saturates at min(threads, {n_eps} episodes); \
         stream-axis keeps scaling with threads."
    );
}
