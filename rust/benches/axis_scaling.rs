//! Episode-axis vs stream-axis CPU scaling — registered as the
//! `axis_scaling` suite in `episodes_gpu::bench`. The suite body lives in
//! `src/bench/suites/axis_scaling.rs`.
//!
//! Run: `cargo bench --bench axis_scaling
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("axis_scaling")
}
