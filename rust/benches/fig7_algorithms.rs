//! Fig. 7 reproduction: PTPE vs MapConcatenate vs Hybrid on Sym26.
//!
//! (a) execution time per episode size at one support threshold;
//! (b) Hybrid speedup over PTPE and over MapConcatenate across support
//!     thresholds.
//!
//! Run: `cargo bench --bench fig7_algorithms`  (add `-- --fast` for a
//! smaller sweep). Paper shape to reproduce: neither pure strategy wins
//! everywhere — PTPE wins at sizes with many candidates, MapConcatenate
//! wins when few episodes leave lanes idle, and Hybrid tracks the winner.

#![allow(deprecated)] // Coordinator shims: migrating to Session incrementally

use episodes_gpu::coordinator::miner::{CountMode, MineConfig};
use episodes_gpu::coordinator::{Coordinator, Strategy};
use episodes_gpu::datasets::sym26::{generate, Sym26Config};
use episodes_gpu::episodes::{candidates, Episode};
use episodes_gpu::util::benchkit::{bench, BenchCfg, Table};
use episodes_gpu::util::cli::Args;

/// Rebuild each level's candidate set exactly as the miner generated it
/// (level-1 alphabet, then joins over the mined frequent sets).
fn level_candidates(
    result: &episodes_gpu::coordinator::miner::MineResult,
    n_types: usize,
    i_set: &[episodes_gpu::episodes::Interval],
    max_level: usize,
) -> Vec<Vec<Episode>> {
    let mut per_level = vec![];
    let mut frontier: Vec<Episode> = vec![];
    for level in 1..=max_level {
        let cands = if level == 1 {
            candidates::level1(n_types)
        } else {
            candidates::next_level(&frontier, i_set)
        };
        if cands.is_empty() {
            break;
        }
        frontier = result
            .frequent
            .iter()
            .filter(|c| c.episode.n() == level)
            .map(|c| c.episode.clone())
            .collect();
        per_level.push(cands);
    }
    per_level
}

fn main() -> Result<(), episodes_gpu::MineError> {
    let args = Args::from_env();
    let fast = args.flag("fast");
    let cfg = Sym26Config::default();
    let stream = generate(&cfg, 7);
    let mut coord = Coordinator::open_default()?;

    let theta = 60;
    let mut mine_cfg = MineConfig::new(theta, cfg.interval_set());
    mine_cfg.mode = CountMode::TwoPass;
    let result = coord.mine(&stream, &mine_cfg)?;
    let per_level = level_candidates(&result, stream.n_types, &cfg.interval_set(), 8);

    let bcfg = BenchCfg {
        warmup_iters: 1,
        min_iters: if fast { 2 } else { 3 },
        max_iters: if fast { 3 } else { 5 },
        budget_ns: 5_000_000_000,
    };

    // --- Fig 7(a): execution time by episode size ---
    // Candidate sets are sampled down to one PTPE batch (512): running
    // MapConcatenate over a 17k-episode level costs ~2*S*C kernel loop
    // steps and takes minutes on this substrate; its disadvantage at large
    // S is already unambiguous at the cap (see EXPERIMENTS.md Fig 7 note).
    let cap = 512usize;
    let mut ta = Table::new(
        &format!("Fig 7(a): execution time by episode size (Sym26, theta={theta}, cap {cap})"),
        &["size", "episodes", "PTPE", "MapConcat", "Hybrid", "winner"],
    );
    for (li, cands) in per_level.iter().enumerate() {
        let n = li + 1;
        if n < 2 || cands.is_empty() {
            continue;
        }
        let cands: Vec<Episode> = cands.iter().take(cap).cloned().collect();
        let cands = &cands;
        let mut times = vec![];
        for strat in [Strategy::PtpeA1, Strategy::MapConcat, Strategy::Hybrid] {
            let m = bench(&format!("n{n}"), &bcfg, || {
                coord.count(cands, &stream, strat).unwrap().iter().sum()
            });
            times.push(m.summary.median);
        }
        let winner = ["PTPE", "MapConcat", "Hybrid"][times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0];
        ta.row(vec![
            n.to_string(),
            cands.len().to_string(),
            format!("{:.1}ms", times[0] / 1e6),
            format!("{:.1}ms", times[1] / 1e6),
            format!("{:.1}ms", times[2] / 1e6),
            winner.to_string(),
        ]);
    }
    ta.print();

    // --- Fig 7(b): Hybrid speedup across support thresholds ---
    let thetas: &[u64] = if fast { &[40, 80] } else { &[40, 60, 120] };
    let mut tb = Table::new(
        "Fig 7(b): Hybrid speedup over PTPE / MapConcatenate by support threshold",
        &["theta", "episodes(n>=2)", "PTPE", "MapConcat", "Hybrid", "vsPTPE", "vsMC"],
    );
    for &th in thetas {
        let mut mc = MineConfig::new(th, cfg.interval_set());
        mc.mode = CountMode::TwoPass;
        mc.max_level = 5;
        let r = coord.mine(&stream, &mc)?;
        let all_cands: Vec<Episode> = level_candidates(&r, stream.n_types, &cfg.interval_set(), 5)
            .into_iter()
            .skip(1) // counting work is levels >= 2
            .flat_map(|lvl| lvl.into_iter().take(512)) // same cap as 7(a)
            .collect();
        if all_cands.is_empty() {
            continue;
        }
        let mut med = vec![];
        for strat in [Strategy::PtpeA1, Strategy::MapConcat, Strategy::Hybrid] {
            let m = bench("theta", &bcfg, || {
                coord.count(&all_cands, &stream, strat).unwrap().iter().sum()
            });
            med.push(m.summary.median);
        }
        tb.row(vec![
            th.to_string(),
            all_cands.len().to_string(),
            format!("{:.1}ms", med[0] / 1e6),
            format!("{:.1}ms", med[1] / 1e6),
            format!("{:.1}ms", med[2] / 1e6),
            format!("{:.2}x", med[0] / med[2]),
            format!("{:.2}x", med[1] / med[2]),
        ]);
    }
    tb.print();
    println!("\nmetrics: {}", coord.metrics.report());
    Ok(())
}
