//! Fig. 7 reproduction: PTPE vs MapConcatenate vs Hybrid on Sym26 —
//! registered as the `fig7_algorithms` suite in `episodes_gpu::bench`
//! (shared measurement loop, `BENCH_fig7_algorithms.json`, baseline
//! gating). The suite body lives in `src/bench/suites/fig7.rs`.
//!
//! Run: `cargo bench --bench fig7_algorithms
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("fig7_algorithms")
}
