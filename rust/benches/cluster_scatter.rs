//! Scatter-gather distributed mining vs single-process — registered as
//! the `cluster_scatter` suite in `episodes_gpu::bench`. The suite body
//! lives in `src/bench/suites/cluster_scatter.rs`.
//!
//! Run: `cargo bench --bench cluster_scatter
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("cluster_scatter")
}
