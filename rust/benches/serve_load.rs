//! Multi-tenant service throughput under closed-loop load (includes the
//! >= 5x repeat-query acceptance floor) — registered as the `serve_load`
//! suite in `episodes_gpu::bench`. The suite body lives in
//! `src/bench/suites/serve_load.rs`.
//!
//! Run: `cargo bench --bench serve_load
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("serve_load")
}
