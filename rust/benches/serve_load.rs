//! Service throughput under a multi-client load: the tentpole metric for
//! the `serve/` layer.
//!
//! Phase 1 measures the pre-service world — a serial loop that re-mines
//! every repeated query from scratch (what every caller did before the
//! service existed). Phase 2 replays a hot-repeat workload through
//! `MineService` (coalescing + result cache) and reports the repeat-query
//! throughput ratio, which must clear 5x, plus p50/p95/p99 latency and
//! the cache hit rate. Phase 3 runs the full mixed scenario set (hot
//! repeats, theta sweeps, distinct datasets, sliding windows) for the
//! realistic-traffic picture and a JSON-able summary line.
//!
//! Run: `cargo bench --bench serve_load [-- --smoke]`

use std::time::Instant;

use episodes_gpu::serve::loadgen::{self, LoadGenConfig, MixWeights, Workload};
use episodes_gpu::serve::{mine_direct, MineService, ServiceConfig};
use episodes_gpu::util::benchkit::Table;
use episodes_gpu::util::cli::{exit_usage, Args};

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let lg = if smoke { LoadGenConfig::smoke() } else { LoadGenConfig::default() };
    let sc = ServiceConfig {
        workers: args.get_usize("workers", 4).unwrap_or_else(exit_usage),
        ..ServiceConfig::default()
    };
    let workload = Workload::build(&lg).unwrap_or_else(exit_usage);

    // Phase 1: serial re-mine baseline over the hot repeats (enough
    // repeats for a stable qps estimate; the point is cost-per-request).
    let serial_requests = if smoke { 12 } else { 20 };
    let t0 = Instant::now();
    for i in 0..serial_requests {
        let q = &workload.hot[i % workload.hot.len()];
        mine_direct(q, sc.strategy, sc.cpu_threads).unwrap_or_else(exit_usage);
    }
    let serial_qps = serial_requests as f64 / t0.elapsed().as_secs_f64();

    // Phase 2: the same hot-repeat pattern through the service.
    let hot_lg = LoadGenConfig {
        mix: MixWeights { hot_repeat: 1, theta_sweep: 0, distinct: 0, sliding_window: 0 },
        ..lg.clone()
    };
    let service = MineService::start(sc.clone()).unwrap_or_else(exit_usage);
    let hot_report = loadgen::run(&service, &workload, &hot_lg);
    let hot_metrics = service.shutdown();
    let speedup = hot_report.qps / serial_qps;

    let mut table = Table::new(
        &format!(
            "repeat-query throughput: {} clients x {} requests, {} workers",
            hot_lg.clients, hot_lg.requests_per_client, sc.workers
        ),
        &["path", "qps", "p50", "p95", "p99", "hit rate"],
    );
    table.row(vec![
        "serial re-mine".into(),
        format!("{serial_qps:.1}"),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    let (p50, p95, p99) = match &hot_report.latency_ns {
        Some(s) => (s.median / 1e6, s.p95 / 1e6, s.p99 / 1e6),
        None => (0.0, 0.0, 0.0),
    };
    table.row(vec![
        "MineService".into(),
        format!("{:.1}", hot_report.qps),
        format!("{p50:.3}ms"),
        format!("{p95:.3}ms"),
        format!("{p99:.3}ms"),
        format!("{:.1}%", hot_metrics.cache.hit_rate() * 100.0),
    ]);
    table.print();
    println!(
        "\nrepeat-query speedup: {speedup:.1}x (coalescing + cache over serial re-mine; \
         acceptance floor 5x)"
    );
    assert!(
        speedup >= 5.0,
        "service repeat-query throughput must beat serial re-mine by >= 5x, got {speedup:.1}x"
    );

    // Phase 3: the full mixed scenario set.
    let service = MineService::start(sc).unwrap_or_else(exit_usage);
    let report = loadgen::run(&service, &workload, &lg);
    let metrics = service.shutdown();
    println!(
        "\nmixed scenario mix ({} clients x {} requests): {:.1} qps, \
         {} completed / {} rejected / {} errors",
        lg.clients,
        lg.requests_per_client,
        report.qps,
        report.completed,
        report.rejected,
        report.errors,
    );
    println!("service: {}", metrics.report());
    println!("\n{}", report.to_json());
}
