//! Incremental sliding-window commits vs batch re-mine — registered as
//! the `stream_incremental` suite in `episodes_gpu::bench`. The suite
//! body lives in `src/bench/suites/stream_incremental.rs`.
//!
//! Run: `cargo bench --bench stream_incremental
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("stream_incremental")
}
