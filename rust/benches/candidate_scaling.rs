//! Arena bucketed candidate generation vs the legacy quadratic join —
//! registered as the `candidate_scaling` suite in `episodes_gpu::bench`.
//! The suite body lives in `src/bench/suites/candidate_scaling.rs`.
//!
//! Run: `cargo bench --bench candidate_scaling
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("candidate_scaling")
}
