//! Table 1 + Fig. 8 reproduction: PTPE/MapConcatenate crossover points by
//! episode size, and the f(N) = a/N + b vs a*N + b fit comparison.
//!
//! Two views, per DESIGN.md §5 substitution 1:
//!
//! 1. **Measured on this substrate** — PTPE and MapConcatenate timed on
//!    growing episode-batch sizes S; the crossover is the S where PTPE
//!    first wins. Interpret-mode PJRT serializes the Pallas grid, so the
//!    segment-parallelism that gives MapConcatenate its paper-scale wins
//!    has no physical parallelism to exploit here: measured crossovers are
//!    small (driven by PTPE's fixed full-batch cost vs MapConcatenate's
//!    partial-scan structure). The *direction* (crossover falls as N
//!    rises) still reproduces.
//! 2. **GTX280 analytical model** — the paper's Eq. 1 utilization
//!    threshold `MP * B_MP * T_B` per level from the occupancy model,
//!    scaled by the paper's own f(N): reproduces Table 1's magnitudes.
//!
//! Both series are fitted with a/N + b and a*N + b (Fig. 8).
//!
//! Run: `cargo bench --bench table1_crossover [-- --fast]`

#![allow(deprecated)] // Coordinator shims: migrating to Session incrementally

use episodes_gpu::coordinator::{Coordinator, Strategy};
use episodes_gpu::datasets::sym26::{generate, Sym26Config};
use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::gpu_model::crossover::{fit_comparison, CrossoverModel, PAPER_TABLE1};
use episodes_gpu::gpu_model::occupancy::{a1_resources, GTX280};
use episodes_gpu::util::benchkit::{bench, BenchCfg, Table};
use episodes_gpu::util::cli::Args;
use episodes_gpu::util::rng::Rng;
use episodes_gpu::util::stats::{inverse_fit, linear_fit};

fn episodes_of_size(rng: &mut Rng, n: usize, count: usize, n_types: i32) -> Vec<Episode> {
    let iv = Interval::new(5, 15);
    (0..count)
        .map(|_| {
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, n_types - 1)).collect();
            Episode::new(types, vec![iv; n - 1])
        })
        .collect()
}

fn fit_table(title: &str, series: &[(&str, Vec<(usize, f64)>)]) {
    let mut fig8 = Table::new(
        title,
        &["points", "a/N+b (a, b, SSE)", "a*N+b (a, b, SSE)", "better"],
    );
    for (name, pts) in series {
        let xs: Vec<f64> = pts.iter().map(|&(n, _)| n as f64).collect();
        let ys: Vec<f64> = pts.iter().map(|&(_, c)| c).collect();
        let (ai, bi, si) = inverse_fit(&xs, &ys);
        let (al, bl, sl) = linear_fit(&xs, &ys);
        let (sse_inv, sse_lin) = fit_comparison(pts);
        fig8.row(vec![
            name.to_string(),
            format!("({ai:.1}, {bi:.1}, {si:.1})"),
            format!("({al:.1}, {bl:.1}, {sl:.1})"),
            if sse_inv <= sse_lin { "a/N+b".into() } else { "a*N+b".into() },
        ]);
    }
    fig8.print();
}

fn main() -> Result<(), episodes_gpu::MineError> {
    let args = Args::from_env();
    let fast = args.flag("fast");
    let cfg = Sym26Config::default();
    // the crossover regime is probed on a partition-sized stream — the
    // workload MapConcatenate targets (few episodes over one partition)
    let full = generate(&cfg, 7);
    let stream = full.window(full.t_begin() - 1, full.t_begin() + 20_000);
    let mut coord = Coordinator::open_default()?;
    let mut rng = Rng::new(0x7AB1E1);

    let bcfg = BenchCfg {
        warmup_iters: 1,
        min_iters: 2,
        max_iters: if fast { 3 } else { 5 },
        budget_ns: 1_500_000_000,
    };
    let probes: Vec<usize> =
        if fast { vec![1, 4, 16] } else { vec![1, 2, 4, 8, 16, 32, 64] };
    let sizes: Vec<usize> = if fast { vec![3, 5, 7] } else { vec![3, 4, 5, 6, 7, 8] };

    let mut measured: Vec<(usize, f64)> = vec![];
    let mut table = Table::new(
        "Table 1 (measured): crossover points on this substrate",
        &["size", "crossover", "detail (S: ptpe-ms/mapcat-ms)"],
    );
    for &n in &sizes {
        let mut detail = String::new();
        let mut crossover: Option<f64> = None;
        let mut prev_s: Option<usize> = None;
        for &s in &probes {
            let eps = episodes_of_size(&mut rng, n, s, stream.n_types as i32);
            let pt = bench("p", &bcfg, || {
                coord.count(&eps, &stream, Strategy::PtpeA1).unwrap().iter().sum()
            })
            .summary
            .median;
            let mc = bench("m", &bcfg, || {
                coord.count(&eps, &stream, Strategy::MapConcat).unwrap().iter().sum()
            })
            .summary
            .median;
            detail.push_str(&format!("{s}:{:.0}/{:.0} ", pt / 1e6, mc / 1e6));
            if crossover.is_none() && pt <= mc {
                crossover = Some(match prev_s {
                    Some(p) => (p + s) as f64 / 2.0,
                    None => 0.5,
                });
            }
            prev_s = Some(s);
        }
        let c = crossover.unwrap_or(*probes.last().unwrap() as f64 * 2.0);
        measured.push((n, c));
        table.row(vec![n.to_string(), format!("{c:.1}"), detail]);
    }
    table.print();

    // --- GTX280 analytical model: Eq. 1/2 utilization thresholds ---
    let mut model_tab = Table::new(
        "Table 1 (GTX280 model): utilization threshold MP*B_MP*T_B by level",
        &["size", "T_B (A1)", "S* = MP*B_MP*T_B", "paper crossover"],
    );
    let mut model_pts: Vec<(usize, f64)> = vec![];
    for &(n, paper_c) in PAPER_TABLE1 {
        let r = a1_resources(n, coord.rt.manifest().k_slots);
        let tb = GTX280.max_threads(&r);
        let s_star = GTX280.full_utilization_threshold(&r);
        model_pts.push((n, s_star as f64));
        model_tab.row(vec![
            n.to_string(),
            tb.to_string(),
            s_star.to_string(),
            format!("{paper_c:.0}"),
        ]);
    }
    model_tab.print();

    // --- Fig 8: functional-form comparison across all three series ---
    fit_table(
        "Fig 8: crossover fits (lower SSE wins)",
        &[
            ("measured (this substrate)", measured.clone()),
            ("GTX280 model S*", model_pts),
            ("paper Table 1", PAPER_TABLE1.to_vec()),
        ],
    );

    let model = CrossoverModel::fit(&measured);
    println!(
        "\nfitted dispatch model for this substrate: crossover(N) = {:.1}/N + {:.1}",
        model.a, model.b
    );
    let paper = CrossoverModel::paper_default();
    println!("paper-default dispatch model: crossover(N) = {:.1}/N + {:.1}", paper.a, paper.b);
    Ok(())
}
