//! Table 1 + Fig. 8 reproduction: strategy crossover points and the
//! f(N) fit comparison — registered as the `table1_crossover` suite in
//! `episodes_gpu::bench`. The suite body lives in
//! `src/bench/suites/table1.rs`.
//!
//! Run: `cargo bench --bench table1_crossover
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("table1_crossover")
}
