//! Ablations over the design choices DESIGN.md §6 calls out:
//!
//! 1. **Bounded list depth K** — exactness vs footprint: fraction of
//!    random and neural-like episodes whose bounded count diverges from
//!    the unbounded Algorithm 1, per K.
//! 2. **Concatenate fold vs log-tree** — merge cost of the two
//!    implementations at growing segment counts (the GPU needs the tree;
//!    the host fold is O(P) with small constants).
//! 3. **Hybrid dispatch rules** — paper Eq. 2 crossover form vs the
//!    substrate cost model, scored by how often each picks the truly
//!    faster strategy.
//!
//! Run: `cargo bench --bench ablation_k_slots [-- --fast]`

#![allow(deprecated)] // Coordinator shims: migrating to Session incrementally

use std::time::Instant;

use episodes_gpu::coordinator::mapconcat::{concatenate_fold, concatenate_tree};
use episodes_gpu::coordinator::{Coordinator, Strategy};
use episodes_gpu::datasets::sym26::{generate, Sym26Config};
use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::gpu_model::crossover::{CostModel, CrossoverModel};
use episodes_gpu::mining::serial;
use episodes_gpu::util::benchkit::Table;
use episodes_gpu::util::cli::Args;
use episodes_gpu::util::rng::Rng;

fn main() -> Result<(), episodes_gpu::MineError> {
    let args = Args::from_env();
    let fast = args.flag("fast");

    // --- 1. K ablation ---
    let mut rng = Rng::new(0xAB1A);
    let cfg = Sym26Config::default();
    let sym = generate(&cfg, 7);
    let trials = if fast { 60 } else { 300 };
    let mut ktab = Table::new(
        "Ablation: bounded list depth K vs exactness (vs unbounded Alg. 1)",
        &["K", "divergent (dense random)", "divergent (Sym26)", "state bytes/lane (N=5)"],
    );
    // dense random stream: the worst case for truncation
    let mut pairs = vec![];
    let mut t = 0;
    for _ in 0..6000 {
        t += rng.range_i32(0, 2);
        pairs.push((rng.range_i32(0, 3), t));
    }
    let dense = EventStream::from_pairs(pairs, 4);
    for k in [1usize, 2, 4, 8, 16] {
        let mut div_dense = 0;
        let mut div_sym = 0;
        for _ in 0..trials {
            let n = rng.range_i32(2, 4) as usize;
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 3)).collect();
            let ivs: Vec<Interval> = (0..n - 1)
                .map(|_| {
                    let lo = rng.range_i32(0, 3);
                    Interval::new(lo, lo + rng.range_i32(1, 10))
                })
                .collect();
            let ep = Episode::new(types, ivs);
            if serial::count_a1_bounded(&ep, &dense, k) != serial::count_a1(&ep, &dense) {
                div_dense += 1;
            }
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 25)).collect();
            let ep = Episode::new(types, vec![Interval::new(5, 15); n - 1]);
            if serial::count_a1_bounded(&ep, &sym, k) != serial::count_a1(&ep, &sym) {
                div_sym += 1;
            }
        }
        ktab.row(vec![
            k.to_string(),
            format!("{:.1}%", 100.0 * div_dense as f64 / trials as f64),
            format!("{:.1}%", 100.0 * div_sym as f64 / trials as f64),
            (4 * 5 * k).to_string(),
        ]);
    }
    ktab.print();

    // --- 2. fold vs tree merge cost ---
    let ep = Episode::new(vec![0, 1, 2], vec![Interval::new(5, 15); 2]);
    let mut mtab = Table::new(
        "Ablation: Concatenate fold vs log-tree merge cost (host-side)",
        &["segments", "fold", "tree", "counts equal"],
    );
    for p in [8usize, 64, 512, 4096] {
        let taus: Vec<i32> = {
            let t0 = sym.t_begin() as i64 - 1;
            let span = sym.t_end() as i64 - t0;
            (0..p as i64)
                .map(|i| (t0 + span * i / p as i64) as i32)
                .chain([sym.t_end()])
                .collect()
        };
        let tuples = serial::mapcat_map(&ep, &sym, &taus, 8);
        let reps = if fast { 50 } else { 500 };
        let t0 = Instant::now();
        let mut f = (0, 0);
        for _ in 0..reps {
            f = std::hint::black_box(concatenate_fold(&tuples));
        }
        let fold_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
        let t0 = Instant::now();
        let mut tr = (0, 0);
        for _ in 0..reps {
            tr = std::hint::black_box(concatenate_tree(&tuples));
        }
        let tree_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
        mtab.row(vec![
            p.to_string(),
            format!("{:.1}us", fold_ns / 1e3),
            format!("{:.1}us", tree_ns / 1e3),
            (f.0 == tr.0).to_string(),
        ]);
    }
    mtab.print();

    // --- 3. dispatch-rule ablation ---
    let mut coord = Coordinator::open_default()?;
    let window = sym.window(sym.t_begin() - 1, sym.t_begin() + 20_000);
    let mf = *coord.rt.manifest();
    let cost = CostModel::substrate_default(mf.m_episodes, mf.c_chunk);
    let paper = CrossoverModel::paper_default();
    let substrate = CrossoverModel::substrate_default();
    let mut dtab = Table::new(
        "Ablation: Hybrid dispatch rules vs ground truth (which is faster)",
        &["S", "N", "truth", "paper Eq.2", "substrate a/N+b", "cost model"],
    );
    let mut scores = [0usize; 3];
    let mut total = 0usize;
    let probe_s: &[usize] = if fast { &[2, 64] } else { &[1, 4, 16, 64, 256] };
    let probe_n: &[usize] = if fast { &[3, 6] } else { &[3, 4, 6, 8] };
    for &n in probe_n {
        for &s in probe_s {
            let eps: Vec<Episode> = (0..s)
                .map(|_| {
                    let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, 25)).collect();
                    Episode::new(types, vec![Interval::new(5, 15); n - 1])
                })
                .collect();
            let t0 = Instant::now();
            coord.count(&eps, &window, Strategy::PtpeA1)?;
            let pt = t0.elapsed();
            let t0 = Instant::now();
            coord.count(&eps, &window, Strategy::MapConcat)?;
            let mc = t0.elapsed();
            let truth = pt <= mc;
            let picks = [
                paper.choose_ptpe(s, n),
                substrate.choose_ptpe(s, n),
                cost.choose_ptpe(s, n, window.len()),
            ];
            for (i, &p) in picks.iter().enumerate() {
                if p == truth {
                    scores[i] += 1;
                }
            }
            total += 1;
            dtab.row(vec![
                s.to_string(),
                n.to_string(),
                if truth { "PTPE" } else { "MC" }.into(),
                if picks[0] { "PTPE" } else { "MC" }.into(),
                if picks[1] { "PTPE" } else { "MC" }.into(),
                if picks[2] { "PTPE" } else { "MC" }.into(),
            ]);
        }
    }
    dtab.print();
    println!(
        "\ndispatch accuracy: paper {}/{total}, substrate-crossover {}/{total}, cost-model {}/{total}",
        scores[0], scores[1], scores[2]
    );
    Ok(())
}
