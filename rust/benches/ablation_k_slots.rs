//! Ablations: bounded-K exactness, fold-vs-tree merge cost, dispatch
//! rules — registered as the `ablation_k_slots` suite in
//! `episodes_gpu::bench`. The suite body lives in
//! `src/bench/suites/ablation.rs`.
//!
//! Run: `cargo bench --bench ablation_k_slots
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("ablation_k_slots")
}
