//! Fig. 10 reproduction: A1 vs A2 profiler counters on the 2-1-33 analog
//! at support threshold ~1650-equivalent.
//!
//! The paper used the CUDA Visual Profiler; this substrate has no such
//! hardware, so the counters come from the analytical GTX280 model fed by
//! instrumented SIMT-warp simulation (`mining::telemetry`, DESIGN.md §5
//! substitution 3):
//!   (a) local-memory loads/stores — A1 spills its occurrence lists
//!       (paper: 17 regs + 80 B local/thread), A2 is register-resident
//!       (13 regs, zero local traffic);
//!   (b) divergent branches per warp of 32 episode lanes.
//! Also prints the occupancy table (threads/block by episode size,
//! §6.1.2) that motivates the two-pass design.
//!
//! Run: `cargo bench --bench fig10_profiler [-- --fast]`

use episodes_gpu::datasets::culture::{generate, CultureConfig};
use episodes_gpu::episodes::{candidates, Episode, Interval};
use episodes_gpu::gpu_model::occupancy::{a1_resources, a2_resources, GTX280};
use episodes_gpu::mining::telemetry::{profile_a1, profile_a2};
use episodes_gpu::util::benchkit::Table;
use episodes_gpu::util::cli::Args;
use episodes_gpu::util::rng::Rng;

fn main() -> Result<(), episodes_gpu::MineError> {
    let args = Args::from_env();
    let fast = args.flag("fast");
    let cfg = CultureConfig::day(33);
    let stream = generate(&cfg, 11);
    let stream = if fast {
        stream.window(stream.t_begin() - 1, stream.t_begin() + 20_000)
    } else {
        stream
    };
    let k = 8;

    // candidate population per episode size: the level-2 cross product
    // joined upward via actual counts, as in the paper's run
    let iv = Interval::new(cfg.d_low, cfg.d_high);
    let mut rng = Rng::new(0xF16);
    let mut t = Table::new(
        "Fig 10: A1 vs A2 profiler counters (2-1-33 analog, SIMT warp simulation)",
        &["size", "episodes", "A1 local ld/st", "A2 local ld/st", "A1 divergent", "A2 divergent"],
    );
    let sizes: Vec<usize> = if fast { vec![2, 3] } else { vec![2, 3, 4, 5] };
    for n in sizes {
        // representative candidate batch at this size: random type
        // sequences over the culture alphabet with the physiological
        // constraint (what the counting phase sees mid-lattice)
        let count = if fast { 64 } else { 256 };
        let eps: Vec<Episode> = if n == 2 {
            candidates::level2(&candidates::level1(stream.n_types), &[iv])
                .into_iter()
                .take(count)
                .collect()
        } else {
            (0..count)
                .map(|_| {
                    let types: Vec<i32> =
                        (0..n).map(|_| rng.range_i32(0, stream.n_types as i32 - 1)).collect();
                    Episode::new(types, vec![iv; n - 1])
                })
                .collect()
        };
        let c1 = profile_a1(&eps, &stream, k);
        let c2 = profile_a2(&eps, &stream);
        t.row(vec![
            n.to_string(),
            eps.len().to_string(),
            format!("{}/{}", c1.local_loads, c1.local_stores),
            format!("{}/{}", c2.local_loads, c2.local_stores),
            c1.divergent_branches.to_string(),
            c2.divergent_branches.to_string(),
        ]);
    }
    t.print();

    // occupancy table (the paper's §6.1.2 thread-budget arithmetic)
    let mut occ = Table::new(
        "GTX280 occupancy model: max threads/block and full-utilization threshold",
        &["size", "A1 shared B/thr", "A1 T_B", "A1 S*", "A2 shared B/thr", "A2 T_B", "A2 S*"],
    );
    for n in 1..=8 {
        let r1 = a1_resources(n, k);
        let r2 = a2_resources(n);
        occ.row(vec![
            n.to_string(),
            r1.shared_bytes_per_thread.to_string(),
            GTX280.max_threads(&r1).to_string(),
            GTX280.full_utilization_threshold(&r1).to_string(),
            r2.shared_bytes_per_thread.to_string(),
            GTX280.max_threads(&r2).to_string(),
            GTX280.full_utilization_threshold(&r2).to_string(),
        ]);
    }
    occ.print();
    println!(
        "\nshape check (paper Fig 10): A2 local traffic == 0 everywhere; \
         A1 local traffic and divergence grow with episode size."
    );
    Ok(())
}
