//! Fig. 10 reproduction: A1 vs A2 profiler counters + GTX280 occupancy —
//! registered as the `fig10_profiler` suite in `episodes_gpu::bench`. The
//! suite body lives in `src/bench/suites/fig10.rs`.
//!
//! Run: `cargo bench --bench fig10_profiler
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("fig10_profiler")
}
