//! Fig. 9 reproduction: one-pass vs two-pass (A2+A1) counting —
//! registered as the `fig9_twopass` suite in `episodes_gpu::bench`. The
//! suite body lives in `src/bench/suites/fig9.rs`.
//!
//! Run: `cargo bench --bench fig9_twopass
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("fig9_twopass")
}
