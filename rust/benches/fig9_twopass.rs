//! Fig. 9 reproduction: one-pass (A1/Hybrid) vs two-pass (A2+A1) counting.
//!
//! (a) execution time by episode size on the day-35 culture at one
//!     support threshold, with the elimination fraction per level;
//! (b) two-pass speedup over one-pass across support thresholds on all
//!     three culture datasets.
//!
//! Paper shape to reproduce: two-pass wins wherever the A2 pass culls a
//! large fraction of candidates (paper: 99.9% culled at size 4 =>
//! 3.6x on that size, 1.2x-2.8x overall).
//!
//! Run: `cargo bench --bench fig9_twopass [-- --fast]`

#![allow(deprecated)] // Coordinator shims: migrating to Session incrementally

use episodes_gpu::coordinator::miner::{CountMode, MineConfig};
use episodes_gpu::coordinator::{Coordinator, Strategy};
use episodes_gpu::datasets::culture::{generate, CultureConfig};
use episodes_gpu::episodes::{candidates, Episode};
use episodes_gpu::util::benchkit::{bench, BenchCfg, Table};
use episodes_gpu::util::cli::Args;

fn level_candidate_sets(
    coord: &mut Coordinator,
    stream: &episodes_gpu::events::EventStream,
    cfg: &CultureConfig,
    theta: u64,
    max_level: usize,
) -> Result<Vec<Vec<Episode>>, episodes_gpu::MineError> {
    let mut mc = MineConfig::new(theta, cfg.interval_set());
    mc.mode = CountMode::TwoPass;
    mc.max_level = max_level;
    let result = coord.mine(stream, &mc)?;
    let mut per_level = vec![];
    let mut frontier: Vec<Episode> = vec![];
    for level in 1..=max_level {
        let cands = if level == 1 {
            candidates::level1(stream.n_types)
        } else {
            candidates::next_level(&frontier, &cfg.interval_set())
        };
        if cands.is_empty() {
            break;
        }
        frontier = result
            .frequent
            .iter()
            .filter(|c| c.episode.n() == level)
            .map(|c| c.episode.clone())
            .collect();
        per_level.push(cands);
    }
    Ok(per_level)
}

fn main() -> Result<(), episodes_gpu::MineError> {
    let args = Args::from_env();
    let fast = args.flag("fast");
    let mut coord = Coordinator::open_default()?;
    let bcfg = BenchCfg {
        warmup_iters: 1,
        min_iters: 2,
        max_iters: if fast { 3 } else { 4 },
        budget_ns: 4_000_000_000,
    };

    // --- Fig 9(a): per-size breakdown on day 35 ---
    let cfg35 = CultureConfig::day(35);
    let stream35 = generate(&cfg35, 11);
    let theta35 = 140;
    let per_level = level_candidate_sets(&mut coord, &stream35, &cfg35, theta35, 6)?;
    let mut ta = Table::new(
        &format!("Fig 9(a): one-pass vs two-pass by episode size (2-1-35, theta={theta35})"),
        &["size", "episodes", "one-pass", "two-pass", "culled", "culled%", "speedup"],
    );
    for (li, cands) in per_level.iter().enumerate() {
        let n = li + 1;
        if n < 2 || cands.is_empty() {
            continue;
        }
        let one = bench("one", &bcfg, || {
            coord.count(cands, &stream35, Strategy::Hybrid).unwrap().iter().sum()
        })
        .summary
        .median;
        let mut culled = 0u64;
        let two = bench("two", &bcfg, || {
            let out = coord.count_two_pass(cands, &stream35, theta35).unwrap();
            culled = out.culled;
            out.counts.iter().sum()
        })
        .summary
        .median;
        ta.row(vec![
            n.to_string(),
            cands.len().to_string(),
            format!("{:.1}ms", one / 1e6),
            format!("{:.1}ms", two / 1e6),
            culled.to_string(),
            format!("{:.1}%", 100.0 * culled as f64 / cands.len() as f64),
            format!("{:.2}x", one / two),
        ]);
    }
    ta.print();

    // --- Fig 9(b): overall speedup across datasets and thresholds ---
    let mut tb = Table::new(
        "Fig 9(b): two-pass speedup over one-pass (all culture datasets)",
        &["dataset", "theta", "episodes", "one-pass", "two-pass", "speedup"],
    );
    let days: &[(u32, &[u64])] = if fast {
        &[(35, &[140, 200])]
    } else {
        &[(33, &[40, 90]), (34, &[85, 180]), (35, &[140, 300])]
    };
    for &(day, thetas) in days {
        let cfg = CultureConfig::day(day);
        let stream = generate(&cfg, 11);
        for &th in thetas {
            let per_level = level_candidate_sets(&mut coord, &stream, &cfg, th, 5)?;
            let all: Vec<Episode> = per_level.into_iter().skip(1).flatten().collect();
            if all.is_empty() {
                continue;
            }
            let one = bench("one", &bcfg, || {
                coord.count(&all, &stream, Strategy::Hybrid).unwrap().iter().sum()
            })
            .summary
            .median;
            let two = bench("two", &bcfg, || {
                coord.count_two_pass(&all, &stream, th).unwrap().counts.iter().sum()
            })
            .summary
            .median;
            tb.row(vec![
                format!("2-1-{day}"),
                th.to_string(),
                all.len().to_string(),
                format!("{:.1}ms", one / 1e6),
                format!("{:.1}ms", two / 1e6),
                format!("{:.2}x", one / two),
            ]);
        }
    }
    tb.print();
    Ok(())
}
