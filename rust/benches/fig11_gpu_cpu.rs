//! Fig. 11 reproduction: accelerated counting vs the optimized CPU
//! baseline, across support thresholds on the 2-1-35 analog.
//!
//! The paper's comparison: GPU two-pass (A2+A1) vs a 4-thread CPU
//! implementation of Algorithm 1 with the event-type acceleration
//! structure (§6.4), speedups up to ~15x. Here the "GPU" is the
//! CPU-PJRT-executed vectorized Pallas kernel; the shape to reproduce is
//! batched-vectorized counting beating the scalar multithreaded baseline,
//! with the gap growing as the candidate count rises (lower thresholds).
//!
//! Run: `cargo bench --bench fig11_gpu_cpu [-- --fast]`

#![allow(deprecated)] // Coordinator shims: migrating to Session incrementally

use episodes_gpu::coordinator::miner::{CountMode, MineConfig};
use episodes_gpu::coordinator::{Coordinator, Strategy};
use episodes_gpu::datasets::culture::{generate, CultureConfig};
use episodes_gpu::episodes::{candidates, Episode};
use episodes_gpu::util::benchkit::{bench, BenchCfg, Table};
use episodes_gpu::util::cli::Args;

fn main() -> Result<(), episodes_gpu::MineError> {
    let args = Args::from_env();
    let fast = args.flag("fast");
    let cfg = CultureConfig::day(35);
    let stream = generate(&cfg, 11);
    let mut coord = Coordinator::open_default()?;
    coord.cpu_threads = 4; // the paper's quad-core baseline
    let bcfg = BenchCfg {
        warmup_iters: 1,
        min_iters: 2,
        max_iters: if fast { 3 } else { 4 },
        budget_ns: 8_000_000_000,
    };

    let thetas: &[u64] = if fast { &[200] } else { &[140, 200, 320] };
    let mut t = Table::new(
        "Fig 11: accelerated two-pass vs 4-thread CPU baseline (2-1-35)",
        &["theta", "episodes", "cpu-4t", "accel(two-pass)", "speedup"],
    );
    for &th in thetas {
        // build the candidate population the counting phase sees
        let mut mc = MineConfig::new(th, cfg.interval_set());
        mc.mode = CountMode::TwoPass;
        mc.max_level = 5;
        let result = coord.mine(&stream, &mc)?;
        let mut frontier: Vec<Episode> = vec![];
        let mut all: Vec<Episode> = vec![];
        for level in 1..=5 {
            let cands = if level == 1 {
                candidates::level1(stream.n_types)
            } else {
                candidates::next_level(&frontier, &cfg.interval_set())
            };
            if cands.is_empty() {
                break;
            }
            if level >= 2 {
                all.extend(cands.iter().cloned());
            }
            frontier = result
                .frequent
                .iter()
                .filter(|c| c.episode.n() == level)
                .map(|c| c.episode.clone())
                .collect();
        }
        if all.is_empty() {
            continue;
        }
        let cpu = bench("cpu", &bcfg, || {
            coord.count(&all, &stream, Strategy::CpuParallel).unwrap().iter().sum()
        })
        .summary
        .median;
        let accel = bench("accel", &bcfg, || {
            coord.count_two_pass(&all, &stream, th).unwrap().counts.iter().sum()
        })
        .summary
        .median;
        t.row(vec![
            th.to_string(),
            all.len().to_string(),
            format!("{:.1}ms", cpu / 1e6),
            format!("{:.1}ms", accel / 1e6),
            format!("{:.2}x", cpu / accel),
        ]);
    }
    t.print();
    Ok(())
}
