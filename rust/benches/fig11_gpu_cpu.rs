//! Fig. 11 reproduction: two-pass counting vs the 4-thread CPU baseline —
//! registered as the `fig11_gpu_cpu` suite in `episodes_gpu::bench`. The
//! suite body lives in `src/bench/suites/fig11.rs`.
//!
//! Run: `cargo bench --bench fig11_gpu_cpu
//!        [-- --smoke] [--json-out <dir>] [--check <baseline.json|dir>]`

fn main() {
    episodes_gpu::bench::cli::bench_binary_main("fig11_gpu_cpu")
}
