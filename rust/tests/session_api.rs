//! Integration tests for the 0.2 public API: `Session` + `CountBackend`.
//!
//! Everything here runs without a PJRT runtime — the point of the
//! redesign is that mining is decoupled from it. A custom mock backend is
//! injected through the builder, error variants are matched structurally,
//! and every CPU-capable backend is checked against the serial reference
//! on a small Sym26 slice. Accelerated-backend equivalence is covered in
//! `integration_runtime.rs` (skips when the runtime is absent).

use std::rc::Rc;

use episodes_gpu::backend::accel::{Dispatch, HybridBackend, PtpeBackend};
use episodes_gpu::backend::cpu::{CpuParallelBackend, CpuSerialBackend};
use episodes_gpu::backend::two_pass::TwoPassBackend;
use episodes_gpu::backend::{self, CountBackend, CountReport};
use episodes_gpu::coordinator::Strategy;
use episodes_gpu::datasets::sym26::{generate, Sym26Config};
use episodes_gpu::episodes::{candidates, Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::gpu_model::crossover::CrossoverModel;
use episodes_gpu::mining::serial;
use episodes_gpu::runtime::Runtime;
use episodes_gpu::{MineError, Session};

/// A counting engine that needs no runtime, no artifacts, no threads:
/// every episode "occurs" a fixed number of times.
struct MockBackend {
    fixed: u64,
}

impl MockBackend {
    fn new(fixed: u64) -> MockBackend {
        MockBackend { fixed }
    }
}

impl CountBackend for MockBackend {
    fn name(&self) -> &str {
        "mock"
    }

    fn supports_n(&self, _n: usize) -> bool {
        true
    }

    fn count(
        &mut self,
        episodes: &[Episode],
        _stream: &EventStream,
    ) -> Result<CountReport, MineError> {
        Ok(CountReport::from_counts(vec![self.fixed; episodes.len()]))
    }
}

fn tiny_stream() -> EventStream {
    EventStream::from_pairs(
        vec![(0, 1), (1, 5), (2, 9), (0, 30), (1, 36), (2, 40), (3, 50)],
        4,
    )
}

/// A ~5-second Sym26 slice plus the level-1/2 candidate population over it.
fn sym26_slice() -> (EventStream, Vec<Episode>) {
    let cfg = Sym26Config::default();
    let full = generate(&cfg, 7);
    let stream = full.window(full.t_begin() - 1, full.t_begin() + 5_000);
    let iv = Interval::new(cfg.d_low, cfg.d_high);
    let singles = candidates::level1(stream.n_types);
    let mut eps = candidates::level2(&singles, &[iv]);
    eps.truncate(120);
    eps.extend(singles.into_iter().take(6));
    (stream, eps)
}

// ---- builder validation -------------------------------------------------

#[test]
fn builder_missing_stream_is_invalid_config() {
    let err = Session::builder().theta(5).interval(Interval::new(0, 9)).build().err().unwrap();
    assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
    assert!(err.to_string().contains("stream"), "{err}");
}

#[test]
fn builder_zero_theta_is_invalid_config() {
    let err = Session::builder()
        .stream(tiny_stream())
        .theta(0)
        .interval(Interval::new(0, 9))
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
    assert!(err.to_string().contains("theta"), "{err}");
}

#[test]
fn builder_bad_max_level_is_invalid_config() {
    let err = Session::builder()
        .stream(tiny_stream())
        .theta(2)
        .interval(Interval::new(0, 9))
        .max_level(0)
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
}

#[test]
fn builder_backend_and_strategy_conflict() {
    let err = Session::builder()
        .stream(tiny_stream())
        .theta(2)
        .interval(Interval::new(0, 9))
        .backend(Box::new(MockBackend::new(1)))
        .strategy(Strategy::CpuSerial)
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
}

// ---- MineError variant mapping ------------------------------------------

#[test]
fn candidate_cap_overflow_is_candidate_explosion() {
    let mut session = Session::builder()
        .stream(tiny_stream())
        .theta(1)
        .interval(Interval::new(0, 10))
        .strategy(Strategy::CpuSerial)
        .max_candidates_per_level(3)
        .build()
        .unwrap();
    match session.mine().err().unwrap() {
        MineError::CandidateExplosion { level, candidates, cap } => {
            assert_eq!(level, 1);
            assert_eq!(candidates, 4); // the alphabet
            assert_eq!(cap, 3);
        }
        other => panic!("wrong variant: {other}"),
    }
}

#[test]
fn strategy_parse_failure_lists_valid_names() {
    let err = Strategy::parse("gpu-go-fast").err().unwrap();
    match &err {
        MineError::UnknownStrategy { given, valid } => {
            assert_eq!(given, "gpu-go-fast");
            assert!(valid.contains(&"hybrid") && valid.contains(&"cpu-parallel"));
        }
        other => panic!("wrong variant: {other}"),
    }
    let msg = err.to_string();
    assert!(msg.contains("hybrid") && msg.contains("cpu-parallel"), "{msg}");
}

#[test]
fn accelerated_strategy_without_runtime_is_runtime_unavailable() {
    // Force runtime resolution away from any real artifact directory.
    match Runtime::new(std::path::Path::new("/nonexistent/artifacts")) {
        Ok(_) => (), // real runtime somehow present; nothing to assert
        Err(e) => assert!(matches!(e, MineError::RuntimeUnavailable { .. }), "{e}"),
    }
    let err = backend::for_strategy(Strategy::PtpeA1, None, 2).err().unwrap();
    assert!(matches!(err, MineError::RuntimeUnavailable { .. }), "{err}");
}

#[test]
fn unsupported_size_falls_back_to_cpu_not_error() {
    let (stream, _) = sym26_slice();
    // A 12-node episode is beyond any artifact set (n_max is 8).
    let iv = Interval::new(5, 15);
    let big = Episode::new((0..12).collect(), vec![iv; 11]);

    // The session default backend (accelerated when possible, CPU
    // otherwise) must count it without error either way.
    let mut session = Session::builder()
        .stream(stream.clone())
        .theta(1)
        .interval(iv)
        .one_pass()
        .build()
        .unwrap();
    let counts = session.count(std::slice::from_ref(&big)).unwrap();
    assert_eq!(counts[0], serial::count_a1(&big, &stream));

    // And when a real runtime is present, the PTPE backend itself must
    // answer with its CPU fallback (counted, not an error).
    if let Ok(rt) = Runtime::open_default() {
        let mut ptpe = PtpeBackend::new(Rc::new(rt), 2);
        assert!(!ptpe.supports_n(12));
        let rep = ptpe.count(std::slice::from_ref(&big), &stream).unwrap();
        assert_eq!(rep.counts[0], serial::count_a1(&big, &stream));
        assert!(rep.metrics.cpu_fallbacks > 0);
    }
}

// ---- mock backend injection (no PJRT runtime anywhere) ------------------

#[test]
fn mock_backend_drives_a_full_session() {
    let mut session = Session::builder()
        .stream(tiny_stream())
        .theta(10)
        .interval(Interval::new(0, 10))
        .one_pass()
        .backend(Box::new(MockBackend::new(42)))
        .max_level(2)
        .build()
        .unwrap();
    assert_eq!(session.backend_name(), "mock");

    let eps = vec![Episode::single(0), Episode::single(1)];
    assert_eq!(session.count(&eps).unwrap(), vec![42, 42]);

    // Mining through the mock: every candidate counts 42 >= theta 10, so
    // both levels fill completely.
    let result = session.mine().unwrap();
    assert_eq!(result.levels.len(), 2);
    assert!(result.frequent.iter().all(|c| c.count == 42));
}

#[test]
fn two_pass_composes_over_a_mock() {
    // Wrapping the mock in TwoPassBackend: relaxed pass (default = exact)
    // culls nothing at theta <= 42, everything at theta > 42.
    let stream = tiny_stream();
    let eps = vec![
        Episode::new(vec![0, 1], vec![Interval::new(0, 10)]),
        Episode::new(vec![1, 2], vec![Interval::new(0, 10)]),
    ];
    let mut keep = TwoPassBackend::new(Box::new(MockBackend::new(42)), 40);
    let rep = keep.count(&eps, &stream).unwrap();
    assert_eq!(rep.culled, 0);
    assert_eq!(rep.counts, vec![42, 42]);

    let mut cull = TwoPassBackend::new(Box::new(MockBackend::new(42)), 50);
    let rep = cull.count(&eps, &stream).unwrap();
    assert_eq!(rep.culled, 2);
}

// ---- backend equivalence on a Sym26 slice -------------------------------

#[test]
fn all_cpu_capable_backends_agree_with_serial_reference() {
    let (stream, eps) = sym26_slice();
    let reference: Vec<u64> = CpuSerialBackend::new().count(&eps, &stream).unwrap().counts;

    // cpu-parallel at several thread counts
    for threads in [1, 2, 8] {
        let got = CpuParallelBackend::new(threads).count(&eps, &stream).unwrap().counts;
        assert_eq!(got, reference, "cpu-parallel x{threads}");
    }

    // hybrid composed over CPU engines: both dispatch arms must agree
    let mut hybrid = HybridBackend::new(
        Box::new(CpuSerialBackend::new()),
        Box::new(CpuParallelBackend::new(4)),
        Dispatch::Crossover(CrossoverModel::paper_default()),
    );
    assert_eq!(hybrid.count(&eps, &stream).unwrap().counts, reference, "hybrid(cpu,cpu)");

    // two-pass over cpu-parallel: decisions exact, survivors exact
    let theta = 8;
    let mut tp = TwoPassBackend::new(Box::new(CpuParallelBackend::new(4)), theta);
    let (out, _) = tp.run(&eps, &stream).unwrap();
    for (i, _) in eps.iter().enumerate() {
        assert_eq!(out.counts[i] >= theta, reference[i] >= theta, "episode {i}");
        if out.relaxed_counts[i] >= theta {
            assert_eq!(out.counts[i], reference[i], "episode {i}");
        }
    }

    // the default backend (whatever substrate is available) agrees too
    let mut default = backend::default_backend(4);
    assert_eq!(
        default.count(&eps, &stream).unwrap().counts,
        reference,
        "default backend {}",
        default.name()
    );
}
