//! The cluster layer's acceptance property: distributed exactness.
//!
//! A `ScatterMiner` over any cluster shape — 1/2/4/8 nodes, varying
//! segment-group sizes, one- or two-pass, bounded-K — must return
//! *byte-identical* results to a single-process `Session::mine` over
//! the same log range: same episodes, same order, same counts, same
//! per-level tallies. The exactness must survive injected faults
//! (node death mid-query, dropped and corrupted replies, slow nodes
//! under hedging) because failover re-plans segments onto survivors
//! rather than dropping them. The wire protocol must reject hostile
//! frames — truncation, garbage, version mismatches — with typed
//! errors, never panics.

use std::path::PathBuf;
use std::time::Duration;

use episodes_gpu::backend::sharded::ShardedBackend;
use episodes_gpu::cluster::{
    proto, AdmissionConfig, ClusterNode, Fault, LocalCluster, NodeState, ScatterConfig,
    ScatterMiner,
};
use episodes_gpu::coordinator::miner::MineResult;
use episodes_gpu::coordinator::Strategy;
use episodes_gpu::episodes::Interval;
use episodes_gpu::events::{EventStream, Tick};
use episodes_gpu::ingest::{RollPolicy, SpikeLog};
use episodes_gpu::serve::loadgen::cluster_curve;
use episodes_gpu::serve::ServiceConfig;
use episodes_gpu::session::{MineOptions, DEFAULT_CANDIDATE_BLOCK};
use episodes_gpu::util::rng::Rng;
use episodes_gpu::Session;

const THETA: u64 = 40;
const MAX_LEVEL: usize = 3;
const CANDIDATE_CAP: usize = 1_000_000;

fn interval() -> Interval {
    Interval::new(0, 5)
}

fn opts() -> MineOptions {
    MineOptions {
        theta: THETA,
        intervals: vec![interval()],
        max_level: MAX_LEVEL,
        max_candidates_per_level: CANDIDATE_CAP,
        candidate_block: DEFAULT_CANDIDATE_BLOCK,
    }
}

/// Fresh scratch directory (removed first, so reruns start clean).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("epgs_cluster_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Ingest a deterministic bursty stream into a fresh multi-segment log.
fn build_log(tag: &str, n_events: usize, seg_events: usize) -> PathBuf {
    let dir = scratch(tag);
    let n_types = 6usize;
    let mut rng = Rng::new(0xC1A5 ^ n_events as u64);
    let mut stream = EventStream::new(n_types);
    let mut t = 0;
    for _ in 0..n_events {
        t += rng.range_i32(0, 2);
        stream.push(rng.range_i32(0, n_types as i32 - 1), t);
    }
    let mut ingestor = SpikeLog::create(&dir, n_types)
        .expect("create log")
        .ingestor(RollPolicy { max_events: seg_events, max_width_ticks: 1_000_000 })
        .expect("ingestor");
    ingestor.append_stream(&stream).expect("append");
    ingestor.finish().expect("finish");
    dir
}

/// Worker-node service: one worker, serial engine — the cluster tests
/// exercise the scatter tier, not intra-node parallelism.
fn node_service() -> ServiceConfig {
    let d = ServiceConfig::default();
    ServiceConfig { workers: 1, strategy: Strategy::CpuSerial, ..d }
}

/// The single-process ground truth: `Session::mine` over the same
/// range, options, pass mode, and K bound.
fn reference(log: &SpikeLog, t_from: Tick, t_to: Tick, two_pass: bool, k: usize) -> MineResult {
    let (stream, _) = log.read_range(t_from, t_to).expect("read range");
    let builder = Session::builder()
        .stream(stream)
        .theta(THETA)
        .interval(interval())
        .two_pass(two_pass)
        .max_level(MAX_LEVEL)
        .max_candidates_per_level(CANDIDATE_CAP)
        .candidate_block(DEFAULT_CANDIDATE_BLOCK);
    let builder = if k == usize::MAX {
        builder.strategy(Strategy::CpuSerial)
    } else {
        builder.backend(Box::new(ShardedBackend::new(1).with_k(k)))
    };
    let mut session = builder.build().expect("build session");
    session.mine().expect("reference mine")
}

fn whole_range(log: &SpikeLog) -> (Tick, Tick) {
    (log.t_begin().expect("non-empty log") - 1, log.t_end().expect("non-empty log"))
}

/// Byte-identical comparison: episodes with counts, in order, plus the
/// per-level tallies (timing fields excluded — they are wall clock).
fn assert_same(tag: &str, got: &MineResult, want: &MineResult) {
    let shape = |r: &MineResult| -> Vec<(String, u64)> {
        r.frequent.iter().map(|c| (c.episode.display(), c.count)).collect()
    };
    assert_eq!(shape(got), shape(want), "{tag}: frequent episodes diverge");
    assert_eq!(got.levels.len(), want.levels.len(), "{tag}: level count diverges");
    for (g, w) in got.levels.iter().zip(&want.levels) {
        assert_eq!(
            (g.level, g.candidates, g.frequent, g.culled_by_a2),
            (w.level, w.candidates, w.frequent, w.culled_by_a2),
            "{tag}: level tallies diverge"
        );
    }
}

// ---------------------------------------------------------------------
// Equality matrix
// ---------------------------------------------------------------------

#[test]
fn distributed_matches_single_process_across_cluster_shapes() {
    let dir = build_log("shapes", 1400, 180);
    let log = SpikeLog::open(&dir).expect("open log");
    assert!(log.segments().len() >= 4, "log must span >= 4 segments");
    let (t_from, t_to) = whole_range(&log);
    let want_one = reference(&log, t_from, t_to, false, usize::MAX);
    let want_two = reference(&log, t_from, t_to, true, usize::MAX);
    assert!(!want_one.frequent.is_empty(), "degenerate fixture: nothing frequent");

    for &nodes in &[1usize, 2, 4, 8] {
        let cluster = LocalCluster::start(&dir, nodes, node_service()).expect("cluster");
        for &group in &[1usize, 3] {
            let cfg = ScatterConfig { group_segments: group, ..ScatterConfig::default() };
            let miner = ScatterMiner::connect(&dir, cluster.links(), cfg).expect("connect");
            for &two_pass in &[false, true] {
                let tag = format!("nodes={nodes} group={group} two_pass={two_pass}");
                let got = miner.mine_all(&opts(), two_pass, "equality").expect("scatter mine");
                let want = if two_pass { &want_two } else { &want_one };
                assert_same(&tag, &got, want);
            }
        }
    }
}

#[test]
fn bounded_k_distributed_matches_bounded_reference() {
    let dir = build_log("bounded_k", 1100, 160);
    let log = SpikeLog::open(&dir).expect("open log");
    let (t_from, t_to) = whole_range(&log);
    let k = 2usize;
    let cluster = LocalCluster::start(&dir, 3, node_service()).expect("cluster");
    let cfg = ScatterConfig { k, group_segments: 2, ..ScatterConfig::default() };
    let miner = ScatterMiner::connect(&dir, cluster.links(), cfg).expect("connect");
    for &two_pass in &[false, true] {
        let got = miner.mine_all(&opts(), two_pass, "bounded").expect("scatter mine");
        let want = reference(&log, t_from, t_to, two_pass, k);
        assert_same(&format!("k={k} two_pass={two_pass}"), &got, &want);
    }
}

#[test]
fn sub_range_query_matches_single_process() {
    let dir = build_log("subrange", 1200, 150);
    let log = SpikeLog::open(&dir).expect("open log");
    let (t0, t1) = whole_range(&log);
    let span = t1 - t0;
    let (t_from, t_to) = (t0 + span / 3, t0 + 2 * span / 3);
    let cluster = LocalCluster::start(&dir, 4, node_service()).expect("cluster");
    let miner =
        ScatterMiner::connect(&dir, cluster.links(), ScatterConfig::default()).expect("connect");
    let got = miner.mine(t_from, t_to, &opts(), false, "range").expect("scatter mine");
    let want = reference(&log, t_from, t_to, false, usize::MAX);
    assert_same("sub-range", &got, &want);
}

// ---------------------------------------------------------------------
// Fault injection: the answer never changes, only the path to it
// ---------------------------------------------------------------------

#[test]
fn node_death_mid_query_replans_onto_survivors() {
    let dir = build_log("death", 1300, 170);
    let log = SpikeLog::open(&dir).expect("open log");
    let (t_from, t_to) = whole_range(&log);
    let want = reference(&log, t_from, t_to, false, usize::MAX);

    let cluster = LocalCluster::start(&dir, 4, node_service()).expect("cluster");
    // node 0 answers two requests, then dies with requests in flight
    cluster.set_fault(0, Fault::DieAfter(2));
    let miner =
        ScatterMiner::connect(&dir, cluster.links(), ScatterConfig::default()).expect("connect");
    let got = miner.mine_all(&opts(), false, "death").expect("mine past node death");
    assert_same("die-after mid-query", &got, &want);
    let m = miner.metrics();
    assert!(m.retries >= 1, "death must force a retry, metrics: {}", m.report());
    assert!(!m.nodes[0].healthy, "the dead node must be marked unhealthy");

    // an already-dead node: every call fails over, nothing is dropped
    cluster.kill(1);
    let got = miner.mine_all(&opts(), false, "death").expect("mine past killed node");
    assert_same("killed before query", &got, &want);

    // survivors-only cluster still answers after a revive of one peer
    cluster.revive(1).expect("revive");
    let got = miner.mine_all(&opts(), false, "death").expect("mine after revive");
    assert_same("after revive", &got, &want);
}

#[test]
fn slow_node_hedging_fires_and_stays_exact() {
    let dir = build_log("hedge", 900, 140);
    let log = SpikeLog::open(&dir).expect("open log");
    let (t_from, t_to) = whole_range(&log);
    let want = reference(&log, t_from, t_to, false, usize::MAX);

    let cluster = LocalCluster::start(&dir, 2, node_service()).expect("cluster");
    cluster.set_fault(0, Fault::Delay(Duration::from_millis(120)));
    let cfg = ScatterConfig {
        hedge_after: Some(Duration::from_millis(20)),
        deadline: Duration::from_secs(10),
        ..ScatterConfig::default()
    };
    let miner = ScatterMiner::connect(&dir, cluster.links(), cfg).expect("connect");
    let got = miner.mine_all(&opts(), false, "hedge").expect("mine with straggler");
    assert_same("hedged straggler", &got, &want);
    let m = miner.metrics();
    assert!(m.hedges >= 1, "the slow node must trigger a hedge, metrics: {}", m.report());
}

#[test]
fn dropped_and_corrupted_replies_fall_back_without_wrong_answers() {
    let dir = build_log("dropcorrupt", 1000, 150);
    let log = SpikeLog::open(&dir).expect("open log");
    let (t_from, t_to) = whole_range(&log);
    let want = reference(&log, t_from, t_to, false, usize::MAX);

    let cluster = LocalCluster::start(&dir, 2, node_service()).expect("cluster");
    // a short deadline keeps the one dropped call from stalling the test
    let cfg = ScatterConfig { deadline: Duration::from_millis(800), ..ScatterConfig::default() };
    let miner = ScatterMiner::connect(&dir, cluster.links(), cfg).expect("connect");

    cluster.set_fault(0, Fault::Drop);
    let got = miner.mine_all(&opts(), false, "faults").expect("mine past dropped replies");
    assert_same("dropped replies", &got, &want);
    assert!(miner.metrics().retries >= 1, "a dropped reply must surface as a retry");

    cluster.set_fault(0, Fault::Corrupt);
    let got = miner.mine_all(&opts(), false, "faults").expect("mine past corrupt replies");
    assert_same("corrupt replies", &got, &want);

    cluster.set_fault(0, Fault::None);
    let got = miner.mine_all(&opts(), false, "faults").expect("mine after faults clear");
    assert_same("faults cleared", &got, &want);
}

// ---------------------------------------------------------------------
// Admission: over-quota tenants shed into typed Busy, never hang
// ---------------------------------------------------------------------

#[test]
fn admission_sheds_over_quota_tenants_under_saturation() {
    let dir = build_log("admission", 700, 180);
    let cluster = LocalCluster::start(&dir, 2, node_service()).expect("cluster");
    // every RPC takes >= 60ms, so concurrent clients genuinely overlap
    cluster.set_fault(0, Fault::Delay(Duration::from_millis(60)));
    cluster.set_fault(1, Fault::Delay(Duration::from_millis(60)));
    let cfg = ScatterConfig {
        admission: AdmissionConfig {
            total_in_flight: 1,
            queue_capacity: 0,
            ..AdmissionConfig::default()
        },
        ..ScatterConfig::default()
    };
    let miner = ScatterMiner::connect(&dir, cluster.links(), cfg).expect("connect");

    let mut small = opts();
    small.max_level = 2;
    let points = cluster_curve(&miner, &small, false, &[3], 2);
    assert_eq!(points.len(), 1);
    let p = &points[0];
    assert_eq!(p.clients, 3);
    assert!(p.completed >= 1, "at least one client must get through: {}", p.report());
    assert!(p.shed >= 1, "capacity 1 with 3 clients must shed: {}", p.report());
    assert_eq!(p.errors, 0, "shedding is Busy, not an error: {}", p.report());
    assert!(miner.metrics().shed >= 1, "the admission counter must record the sheds");
}

// ---------------------------------------------------------------------
// Wire protocol: hostile frames get typed errors, never panics
// ---------------------------------------------------------------------

#[test]
fn wire_rejects_truncated_garbage_and_mismatched_version_frames() {
    // truncated payload: framed bytes cut mid-payload
    let mut framed = Vec::new();
    proto::write_frame(&mut framed, b"{\"v\":1,\"id\":1}").expect("frame");
    let cut = &framed[..framed.len() - 3];
    let err = proto::read_frame(&mut &cut[..]).expect_err("truncated frame must error");
    assert!(format!("{err}").contains("truncated"), "{err}");

    // truncated header: close after 2 of 4 length bytes
    let err = proto::read_frame(&mut &framed[..2]).expect_err("truncated header must error");
    assert!(format!("{err}").contains("truncated"), "{err}");

    // clean EOF between frames is not an error
    let empty: &[u8] = &[];
    assert!(proto::read_frame(&mut &empty[..]).expect("clean close").is_none());

    // a length header past MAX_FRAME is rejected before allocation
    let huge = ((proto::MAX_FRAME + 1) as u32).to_le_bytes();
    let err = proto::read_frame(&mut &huge[..]).expect_err("oversize frame must error");
    assert!(format!("{err}").contains("MAX_FRAME"), "{err}");

    // non-UTF-8 and non-JSON payloads
    assert!(proto::decode_request(&[0xff, 0xfe, 0x01]).is_err());
    assert!(proto::decode_request(b"{\"v\":1,").is_err());

    // a future protocol version is refused with a version message
    let err = proto::decode_request(b"{\"v\":2,\"id\":1,\"req\":{}}")
        .expect_err("version mismatch must error");
    assert!(format!("{err}").contains("version mismatch"), "{err}");

    // a reply envelope must carry ok or err
    assert!(proto::decode_response(b"{\"v\":1,\"id\":1}").is_err());

    // well-formed frames round-trip: id and variant survive
    let bytes = proto::encode_request(7, &proto::Request::Ping);
    let (id, req) = proto::decode_request(&bytes).expect("round trip");
    assert_eq!(id, 7);
    assert!(matches!(req, proto::Request::Ping));
}

#[test]
fn node_answers_undecodable_frames_on_the_zero_channel() {
    let dir = build_log("badframe", 500, 200);
    let state = NodeState::open(&dir, node_service()).expect("open node");

    // garbage in, typed error out — correlation id 0 marks "your frame
    // would not decode" (no request id was recoverable)
    let reply = state.handle_frame(b"definitely not a frame");
    let (id, outcome) = proto::decode_response(&reply).expect("reply must decode");
    assert_eq!(id, 0);
    assert!(outcome.is_err());

    // a good frame on the same state still answers normally
    let reply = state.handle_frame(&proto::encode_request(5, &proto::Request::Ping));
    let (id, outcome) = proto::decode_response(&reply).expect("reply must decode");
    assert_eq!(id, 5);
    match outcome.expect("ping must succeed") {
        proto::Response::Pong { version } => assert_eq!(version, proto::PROTO_VERSION),
        other => panic!("expected Pong, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// TCP loopback: the real sockets, end to end
// ---------------------------------------------------------------------

#[test]
fn tcp_loopback_scatter_matches_single_process() {
    let dir = build_log("tcp", 800, 150);
    let log = SpikeLog::open(&dir).expect("open log");
    let (t_from, t_to) = whole_range(&log);
    let want = reference(&log, t_from, t_to, false, usize::MAX);

    // sandboxes without loopback skip rather than fail
    let Ok(node) = ClusterNode::bind("127.0.0.1:0", &dir, node_service()) else {
        return;
    };
    let (addr, _state) = node.spawn().expect("spawn node");
    let Ok(node2) = ClusterNode::bind("127.0.0.1:0", &dir, node_service()) else {
        return;
    };
    let (addr2, _state2) = node2.spawn().expect("spawn node");

    let miner = ScatterMiner::over_tcp(
        &dir,
        &[addr.to_string(), addr2.to_string()],
        ScatterConfig::default(),
    )
    .expect("connect");
    let want_two = reference(&log, t_from, t_to, true, usize::MAX);
    for &two_pass in &[false, true] {
        let got = miner.mine_all(&opts(), two_pass, "tcp").expect("tcp mine");
        let want = if two_pass { &want_two } else { &want };
        assert_same(&format!("tcp two_pass={two_pass}"), &got, want);
    }
    let m = miner.metrics();
    assert!(m.nodes.iter().any(|n| n.calls > 0), "tcp nodes must have served calls");
}

// ---------------------------------------------------------------------
// Observability: one merged trace + phase profile across the cluster
// ---------------------------------------------------------------------

#[test]
fn profiled_query_produces_one_merged_trace_across_four_nodes() {
    use episodes_gpu::obs::Trace;
    use episodes_gpu::util::json::Json;

    let dir = build_log("trace", 1400, 180);
    let log = SpikeLog::open(&dir).expect("open log");
    let (t_from, t_to) = whole_range(&log);
    let cluster = LocalCluster::start(&dir, 4, node_service()).expect("cluster");
    let miner = ScatterMiner::connect(&dir, cluster.links(), ScatterConfig::default())
        .expect("connect");

    let trace = Trace::started();
    let result = miner
        .mine_traced(t_from, t_to, &opts(), true, "obs", &trace, true)
        .expect("traced mine");

    // instrumentation must not perturb the equality contract
    assert_same("traced", &result, &reference(&log, t_from, t_to, true, usize::MAX));

    // the phase profile rides on the result
    let profile = result.profile.as_ref().expect("profile attached");
    assert_eq!(profile.levels.len(), result.levels.len());
    assert!(profile.shard_map_calls > 0, "cluster counting goes through shard map calls");

    let spans = trace.snapshot();
    let names: Vec<&str> = spans.iter().map(|s| s.name.as_ref()).collect();
    assert!(names.contains(&"plan"), "coordinator plan span missing: {names:?}");
    assert!(
        names.iter().any(|n| n.starts_with("scatter ")),
        "scatter root spans missing: {names:?}"
    );
    assert!(names.contains(&"merge"), "merge span missing: {names:?}");

    // one grafted remote span tree per counting RPC, hung off that RPC's
    // span and tagged with the peer name
    let rpcs: Vec<_> = spans.iter().filter(|s| s.name.starts_with("rpc ")).collect();
    assert!(!rpcs.is_empty(), "no rpc spans recorded");
    let node_roots: Vec<_> = spans
        .iter()
        .filter(|s| s.name == "node.map_count" || s.name == "node.relaxed_count")
        .collect();
    assert_eq!(node_roots.len(), rpcs.len(), "one remote span tree per RPC");
    for root in &node_roots {
        assert!(
            root.node.starts_with("local#"),
            "grafted span must carry the peer name, got {:?}",
            root.node
        );
        assert!(
            rpcs.iter().any(|r| r.id == root.parent),
            "node span must hang off an rpc span"
        );
    }
    // with 8 segments round-robined over 4 nodes, every peer counts
    let peers: std::collections::HashSet<&str> =
        node_roots.iter().map(|s| s.node.as_ref()).collect();
    assert_eq!(peers.len(), 4, "expected counting spans from all 4 nodes: {peers:?}");

    // text tree and lossless JSON export agree with the snapshot
    let tree = trace.render_tree();
    assert!(tree.contains("plan"), "{tree}");
    assert!(tree.contains("@local#"), "{tree}");
    let json = Json::parse(&trace.to_json().render()).expect("trace json parses");
    let exported = json.get("spans").and_then(Json::as_arr).expect("spans array").len();
    assert_eq!(exported, spans.len(), "JSON export is lossless");
}
