//! Pins the obs cost model: recording spans on a *disabled* trace is
//! zero-allocation (and `_fmt` name closures never run), so the mining
//! hot loop can be instrumented unconditionally without perturbing the
//! profiling-off bench baselines.
//!
//! The whole file is one test so the counting allocator sees no
//! concurrent test threads.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use episodes_gpu::obs::Trace;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates to System; the counter is a relaxed atomic.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn disabled_trace_span_recording_is_zero_allocation() {
    let trace = Trace::off();

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..1_000 {
        let root = trace.span("root");
        let child = root.child("child");
        // the name closures must not run on the disabled path — if one
        // did, its format!/to_string would show up in the counter
        let fmt_child = root.child_fmt(|| format!("level {}", 42));
        let fmt_root = trace.span_fmt(|| "computed".to_string());
        drop(fmt_root);
        drop(fmt_child);
        drop(child);
    }
    let allocated = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(allocated, 0, "disabled tracing allocated {allocated} times");

    // sanity: the counter itself works (an enabled trace does allocate)
    let before = ALLOCS.load(Ordering::Relaxed);
    let on = Trace::started();
    {
        let _s = on.span("root");
    }
    assert!(ALLOCS.load(Ordering::Relaxed) > before, "counting allocator is dead");
}
