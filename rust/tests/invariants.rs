//! Property-based invariants over the CPU reference algorithms (the
//! proptest-style suite; the accelerated path is pinned to these
//! references in `integration_runtime.rs`).

use episodes_gpu::coordinator::mapconcat::{concatenate_fold, concatenate_tree};
use episodes_gpu::episodes::{candidates, Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::mining::{cpu_parallel, serial};
use episodes_gpu::util::prop::{forall, small_size};
use episodes_gpu::util::rng::Rng;

fn gen_stream(rng: &mut Rng, max_events: usize, n_types: i32) -> EventStream {
    let n = small_size(rng, max_events);
    let mut pairs = Vec::with_capacity(n);
    let mut t = 0;
    for _ in 0..n {
        t += rng.range_i32(0, 4);
        pairs.push((rng.range_i32(0, n_types - 1), t));
    }
    EventStream::from_pairs(pairs, n_types as usize)
}

fn gen_episode(rng: &mut Rng, n_types: i32, max_n: usize) -> Episode {
    let n = small_size(rng, max_n).max(2);
    let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, n_types - 1)).collect();
    let ivs: Vec<Interval> = (0..n - 1)
        .map(|_| {
            let lo = rng.range_i32(0, 3);
            Interval::new(lo, lo + rng.range_i32(1, 10))
        })
        .collect();
    Episode::new(types, ivs)
}

#[test]
fn prop_theorem_5_1_a2_dominates_a1() {
    forall("count(a2) >= count(a1)", 0xA2A1, 300, |rng| {
        let s = gen_stream(rng, 400, 6);
        let ep = gen_episode(rng, 6, 5);
        let (a1, a2) = (serial::count_a1(&ep, &s), serial::count_a2(&ep, &s));
        if a2 >= a1 {
            Ok(())
        } else {
            Err(format!("{}: a1={a1} a2={a2}", ep.display()))
        }
    });
}

#[test]
fn prop_bounded_list_monotone_in_k() {
    // growing K can only recover occurrences, never lose them
    forall("count_k <= count_{k+1} <= unbounded", 0xB0B0, 200, |rng| {
        let s = gen_stream(rng, 300, 5);
        let ep = gen_episode(rng, 5, 4);
        let unbounded = serial::count_a1(&ep, &s);
        let mut prev = 0;
        for k in 1..=8 {
            let c = serial::count_a1_bounded(&ep, &s, k);
            if c < prev {
                return Err(format!("{}: k={k} c={c} < prev={prev}", ep.display()));
            }
            prev = c;
        }
        if prev > unbounded {
            return Err(format!("bounded {prev} > unbounded {unbounded}"));
        }
        Ok(())
    });
}

#[test]
fn prop_large_k_equals_unbounded() {
    forall("count_k64 == unbounded", 0xCAFE, 200, |rng| {
        let s = gen_stream(rng, 300, 5);
        let ep = gen_episode(rng, 5, 4);
        let b = serial::count_a1_bounded(&ep, &s, 64);
        let u = serial::count_a1(&ep, &s);
        if b == u { Ok(()) } else { Err(format!("{b} != {u}")) }
    });
}

#[test]
fn prop_mapconcat_equals_serial() {
    // the MapConcatenate construction (Map boundary machines + fold)
    // reproduces the single-machine count for any valid segmentation
    forall("mapconcat == serial", 0x3A9C, 150, |rng| {
        let s = gen_stream(rng, 500, 4);
        if s.len() < 20 {
            return Ok(());
        }
        let ep = gen_episode(rng, 4, 4);
        let p = 1 << rng.below(4); // 1, 2, 4, 8 segments
        let t0 = s.t_begin() as i64 - 1;
        let t1 = s.t_end() as i64;
        let span = t1 - t0;
        if span / p < ep.span_max() as i64 + 1 {
            return Ok(()); // infeasible segmentation — planner rejects these
        }
        let taus: Vec<i32> =
            (0..p).map(|i| (t0 + span * i / p) as i32).chain([t1 as i32]).collect();
        let tuples = serial::mapcat_map(&ep, &s, &taus, 8);
        let (total, misses) = concatenate_fold(&tuples);
        let want = serial::count_a1_bounded(&ep, &s, 8);
        // Matched chains are exact; a mismatch must be flagged by a miss
        // (the property the coordinator's PTPE-recount fallback rests on).
        if total == want || misses > 0 {
            Ok(())
        } else {
            Err(format!(
                "silent mismatch {}: p={p} mapcat={total} serial={want}",
                ep.display()
            ))
        }
    });
}

#[test]
fn prop_concatenate_tree_equals_fold() {
    forall("tree == fold", 0x7EE, 150, |rng| {
        let s = gen_stream(rng, 500, 4);
        if s.len() < 20 {
            return Ok(());
        }
        let ep = gen_episode(rng, 4, 4);
        let p = 1 + rng.below(9) as i64; // non-powers-of-two too
        let t0 = s.t_begin() as i64 - 1;
        let span = s.t_end() as i64 - t0;
        if span / p < 1 {
            return Ok(());
        }
        let taus: Vec<i32> =
            (0..p).map(|i| (t0 + span * i / p) as i32).chain([s.t_end()]).collect();
        let tuples = serial::mapcat_map(&ep, &s, &taus, 8);
        let (a, _) = concatenate_fold(&tuples);
        let (b, _) = concatenate_tree(&tuples);
        if a == b { Ok(()) } else { Err(format!("fold {a} != tree {b}")) }
    });
}

#[test]
fn prop_cpu_parallel_equals_serial() {
    forall("parallel == serial", 0x9A11, 60, |rng| {
        let s = gen_stream(rng, 400, 5);
        let n_eps = small_size(rng, 40);
        let eps: Vec<Episode> = (0..n_eps).map(|_| gen_episode(rng, 5, 4)).collect();
        let par = cpu_parallel::count_all_parallel(&eps, &s, 1 + rng.below(6) as usize);
        for (i, ep) in eps.iter().enumerate() {
            let want = serial::count_a1(ep, &s);
            if par[i] != want {
                return Err(format!("{}: par={} serial={}", ep.display(), par[i], want));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_candidate_join_produces_valid_shapes() {
    forall("join shapes", 0x0907, 100, |rng| {
        let n_types = 4;
        let n = 2 + rng.below(3) as usize;
        let n_eps = small_size(rng, 25);
        let iv_choices =
            [Interval::new(0, 10), Interval::new(5, 15), Interval::new(2, 8)];
        let mut seen = std::collections::HashSet::new();
        let mut eps = vec![];
        for _ in 0..n_eps {
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, n_types - 1)).collect();
            let ivs: Vec<Interval> =
                (0..n - 1).map(|_| *rng.choose(&iv_choices)).collect();
            let ep = Episode::new(types, ivs);
            if seen.insert((ep.types.clone(), ep.intervals.clone())) {
                eps.push(ep);
            }
        }
        let next = candidates::join(&eps);
        for c in &next {
            if c.n() != n + 1 {
                return Err(format!("bad size {}", c.n()));
            }
            // head- and tail-drops must be in the frequent input set
            let head = Episode::new(c.types[1..].to_vec(), c.intervals[1..].to_vec());
            let tail =
                Episode::new(c.types[..n].to_vec(), c.intervals[..n - 1].to_vec());
            let in_set = |e: &Episode| {
                eps.iter().any(|x| x.types == e.types && x.intervals == e.intervals)
            };
            if !in_set(&head) || !in_set(&tail) {
                return Err(format!("candidate {} lacks frequent sub-episode", c.display()));
            }
        }
        // completeness: count joinable pairs
        let mut expect = 0;
        for a in &eps {
            for b in &eps {
                if a.types[1..] == b.types[..n - 1] && a.intervals[1..] == b.intervals[..n - 2]
                {
                    expect += 1;
                }
            }
        }
        if next.len() != expect {
            return Err(format!("join produced {} != {} joinable pairs", next.len(), expect));
        }
        Ok(())
    });
}

#[test]
fn prop_partitions_preserve_events() {
    // The serving layer's sliding-window scenario re-mines
    // `partitions_with_starts` output, so the round-trip must be exact:
    // concatenating the partitions reproduces the stream event-for-event
    // (types *and* times — no boundary loss, no duplication), every
    // partition stays inside its tagged (start, start + width] window,
    // and consecutive starts advance by exactly one width.
    forall("partitions_with_starts round-trips", 0x9A77, 200, |rng| {
        let s = gen_stream(rng, 500, 5);
        if s.is_empty() {
            return Ok(());
        }
        // random widths, occasionally wider than the whole recording
        let width = if rng.chance(0.1) {
            s.span() + 1 + rng.below(100) as i32
        } else {
            1 + rng.below(200) as i32
        };
        let parts = s.partitions_with_starts(width);
        let mut types = vec![];
        let mut times = vec![];
        for (start, p) in &parts {
            if let Some(&t) =
                p.times.iter().find(|&&t| t <= *start || t > start + width)
            {
                return Err(format!(
                    "event at t={t} leaked outside window ({start}, {}]",
                    start + width
                ));
            }
            types.extend(p.types.iter().copied());
            times.extend(p.times.iter().copied());
        }
        if types != s.types || times != s.times {
            return Err(format!(
                "union of partitions != stream ({} events vs {}, width {width})",
                times.len(),
                s.len()
            ));
        }
        if let Some(w) = parts.windows(2).find(|w| w[1].0 - w[0].0 != width) {
            return Err(format!("starts not width-spaced: {} -> {}", w[0].0, w[1].0));
        }
        Ok(())
    });
}
