//! The arena-backed candidate engine's acceptance criteria: the
//! block-streamed generate-count-prune loop (SoA arena + bucketed joins +
//! frequency-sorted alphabet remap) must be observationally identical to
//! the legacy per-episode loop it replaced — same frequent episodes in
//! the same order with the same exact counts, same per-level candidate
//! and survivor tallies, and the same typed error when the candidate cap
//! fires — across randomized streams, alphabet sizes from 3 to 512,
//! one- and two-interval constraint sets, and both counting modes.

use episodes_gpu::backend::cpu::CpuSerialBackend;
use episodes_gpu::backend::two_pass::TwoPassBackend;
use episodes_gpu::coordinator::Strategy;
use episodes_gpu::datasets::huge::{self, HugeConfig};
use episodes_gpu::episodes::{candidates, CountedEpisode, Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::mining::serial;
use episodes_gpu::util::rng::Rng;
use episodes_gpu::{MineError, Session};

/// The pre-arena mining loop, reimplemented test-locally over the public
/// candidate generators and the serial counting reference: level-1
/// alphabet scan, suffix-prefix joins over each frequent set, one exact
/// count per heap-allocated candidate, theta filter — in the legacy
/// generation order throughout. Returns the frequent set plus per-level
/// (candidates, frequent) tallies.
#[allow(clippy::type_complexity)]
fn legacy_mine(
    stream: &EventStream,
    theta: u64,
    i_set: &[Interval],
    max_level: usize,
    cap: usize,
) -> Result<(Vec<CountedEpisode>, Vec<(usize, usize)>), MineError> {
    let mut frequent = vec![];
    let mut levels = vec![];
    let mut frontier: Vec<Episode> = vec![];
    for level in 1..=max_level {
        let cands = if level == 1 {
            candidates::level1(stream.n_types)
        } else {
            candidates::next_level(&frontier, i_set)
        };
        if cands.is_empty() {
            break;
        }
        if cands.len() > cap {
            return Err(MineError::CandidateExplosion { level, candidates: cands.len(), cap });
        }
        let mut survivors = vec![];
        for ep in &cands {
            let count = serial::count_a1(ep, stream);
            if count >= theta {
                survivors.push(CountedEpisode { episode: ep.clone(), count });
            }
        }
        levels.push((cands.len(), survivors.len()));
        frontier = survivors.iter().map(|c| c.episode.clone()).collect();
        frequent.extend(survivors);
        if frontier.is_empty() {
            break;
        }
    }
    Ok((frequent, levels))
}

/// Mine through the library's arena-backed loop: one-pass serial, or the
/// two-pass A2-elimination composite over the same serial engine.
fn arena_mine(
    stream: &EventStream,
    theta: u64,
    i_set: &[Interval],
    max_level: usize,
    cap: usize,
    two_pass: bool,
) -> Result<episodes_gpu::coordinator::miner::MineResult, MineError> {
    let builder = Session::builder()
        .stream(stream.clone())
        .theta(theta)
        .intervals(i_set.to_vec())
        .max_level(max_level)
        .max_candidates_per_level(cap)
        .one_pass();
    let builder = if two_pass {
        let serial = Box::new(CpuSerialBackend::new());
        builder.backend(Box::new(TwoPassBackend::new(serial, theta)))
    } else {
        builder.strategy(Strategy::CpuSerial)
    };
    builder.build()?.mine()
}

/// A random stream: `events` events over `n_types` types with 1-4 tick
/// gaps — small alphabets at low theta put every level's frontier in
/// motion across seeds.
fn random_stream(seed: u64, events: usize, n_types: usize) -> EventStream {
    let mut rng = Rng::new(seed);
    let mut pairs = Vec::with_capacity(events);
    let mut t = 0;
    for _ in 0..events {
        t += rng.range_i32(1, 4);
        pairs.push((rng.range_i32(0, n_types as i32 - 1), t));
    }
    EventStream::from_pairs(pairs, n_types)
}

fn assert_equivalent(
    stream: &EventStream,
    theta: u64,
    i_set: &[Interval],
    max_level: usize,
    tag: &str,
) {
    let (want_frequent, want_levels) =
        legacy_mine(stream, theta, i_set, max_level, 2_000_000).unwrap();
    for two_pass in [false, true] {
        let got = arena_mine(stream, theta, i_set, max_level, 2_000_000, two_pass).unwrap();
        assert_eq!(
            got.frequent, want_frequent,
            "{tag} two_pass={two_pass}: frequent set diverged from the legacy loop"
        );
        let got_levels: Vec<(usize, usize)> =
            got.levels.iter().map(|l| (l.candidates, l.frequent)).collect();
        assert_eq!(
            got_levels, want_levels,
            "{tag} two_pass={two_pass}: per-level candidate/survivor tallies diverged"
        );
    }
}

#[test]
fn arena_matches_legacy_on_random_small_alphabets() {
    // Alphabets 3 and 26, |I| in {1, 2}, levels to 5, thetas near the
    // frequency boundary: the regime where generation order, prune
    // decisions, and join bucketing all show through in the output.
    let two_ivs = [Interval::new(0, 5), Interval::new(2, 9)];
    for seed in 0..6u64 {
        let one_iv = [Interval::new(0, 4 + (seed % 3) as i32)];
        for &n_types in &[3usize, 26] {
            let events = if n_types == 3 { 150 } else { 1_200 };
            let stream = random_stream(0xC0FFEE ^ seed.wrapping_mul(0x9E37), events, n_types);
            let theta = if n_types == 3 { 3 + seed % 3 } else { 6 + seed % 4 };
            let tag = format!("seed {seed} alphabet {n_types}");
            assert_equivalent(&stream, theta, &one_iv, 5, &format!("{tag} |I|=1"));
            assert_equivalent(&stream, theta, &two_ivs, 4, &format!("{tag} |I|=2"));
        }
    }
}

#[test]
fn arena_matches_legacy_on_huge_alphabet() {
    // The workload the engine exists for: 512 types, Zipf-skewed, with
    // theta pinned to the 16th-densest type so the level-2+ frontier is
    // small enough for the quadratic legacy reference to stay tractable.
    let cfg = HugeConfig::smoke();
    let stream = huge::generate(&cfg, 0x512);
    let mut counts = stream.type_counts();
    counts.sort_unstable_by_key(|&c| std::cmp::Reverse(c));
    let theta = counts[15].max(1);
    let i_set = cfg.interval_set();
    assert_equivalent(&stream, theta, &i_set, 3, "huge-alphabet");

    // The remap inversion check, explicitly: every reported episode is in
    // *original* type ids (the dense relabeling never leaks), and its
    // count is the serial reference count over the *original* stream.
    let result = arena_mine(&stream, theta, &i_set, 3, 2_000_000, false).unwrap();
    assert!(result.frequent.iter().any(|c| c.episode.n() >= 2), "workload mined nothing");
    for c in &result.frequent {
        assert!(
            c.episode.types.iter().all(|&ty| ty >= 0 && (ty as usize) < stream.n_types),
            "leaked dense id in {:?}",
            c.episode
        );
        assert_eq!(c.count, serial::count_a1(&c.episode, &stream), "{:?}", c.episode);
    }
}

#[test]
fn candidate_cap_errors_match_the_legacy_loop() {
    // theta 1 on a dense 5-type stream explodes at level 2 (25 candidates)
    // and, with a looser cap, at level 3 — the arena loop must fail fast
    // with exactly the legacy loop's typed error, counting the would-be
    // candidates before materializing any of them.
    let stream = random_stream(0xCA9, 200, 5);
    let i_set = [Interval::new(0, 6)];
    for cap in [10usize, 30] {
        let want = match legacy_mine(&stream, 1, &i_set, 4, cap) {
            Err(MineError::CandidateExplosion { level, candidates, cap }) => {
                (level, candidates, cap)
            }
            other => panic!("legacy loop must explode at cap {cap}, got {other:?}"),
        };
        for two_pass in [false, true] {
            match arena_mine(&stream, 1, &i_set, 4, cap, two_pass) {
                Err(MineError::CandidateExplosion { level, candidates, cap }) => {
                    assert_eq!((level, candidates, cap), want, "two_pass={two_pass}");
                }
                other => panic!("arena loop must explode identically, got {other:?}"),
            }
        }
    }
}

#[test]
fn block_size_does_not_change_results() {
    // candidate_block is an execution knob: any block size, from
    // one-candidate-at-a-time to everything-in-one-block, must produce
    // byte-identical results and per-level reports.
    let stream = random_stream(0xB10C, 800, 8);
    let i_set = [Interval::new(0, 5)];
    let theta = 5;
    let reference = arena_mine(&stream, theta, &i_set, 4, 2_000_000, false).unwrap();
    assert!(!reference.frequent.is_empty());
    for block in [1usize, 7, 64, 1 << 20] {
        let mut session = Session::builder()
            .stream(stream.clone())
            .theta(theta)
            .intervals(i_set.to_vec())
            .strategy(Strategy::CpuSerial)
            .one_pass()
            .max_level(4)
            .candidate_block(block)
            .build()
            .unwrap();
        let got = session.mine().unwrap();
        assert_eq!(got.frequent, reference.frequent, "block {block}");
        let tally = |r: &episodes_gpu::coordinator::miner::MineResult| -> Vec<(usize, usize)> {
            r.levels.iter().map(|l| (l.candidates, l.frequent)).collect()
        };
        assert_eq!(tally(&got), tally(&reference), "block {block}");
    }
}
