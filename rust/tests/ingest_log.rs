//! The ingest layer's acceptance property: recovery + mining equivalence.
//!
//! After ingesting a random stream (directly or via the streaming
//! partition producer) and simulating a torn tail write, reopening the
//! `SpikeLog` recovers exactly the sealed segments; and `Session::mine`
//! over any queried time range / alphabet projection returns a result
//! identical to mining the equivalent in-memory slice of the original
//! stream — including when served through `MineService` from a
//! log-backed scenario.

use std::path::PathBuf;
use std::sync::Arc;

use episodes_gpu::coordinator::streaming::{spawn_producer_with, ProducerConfig};
use episodes_gpu::coordinator::Strategy;
use episodes_gpu::datasets;
use episodes_gpu::episodes::Interval;
use episodes_gpu::events::{io, EventStream, EventType, Tick};
use episodes_gpu::ingest::{RangeQuery, RollPolicy, SpikeLog};
use episodes_gpu::serve::loadgen::{LoadGenConfig, Workload};
use episodes_gpu::serve::{MineService, ServiceConfig, SubscribeQuery, WatchLogConfig};
use episodes_gpu::stream::IncrementalConfig;
use episodes_gpu::util::prop::{forall, small_size};
use episodes_gpu::util::rng::Rng;
use episodes_gpu::{MineError, Session};

/// Fresh scratch directory (removed first, so reruns start clean).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("epgs_ingest_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Random valid stream: small alphabet, non-decreasing times, bursty
/// enough that segments get non-trivial histograms.
fn random_stream(rng: &mut Rng, max_events: usize) -> EventStream {
    let n_types = small_size(rng, 6);
    let n_events = small_size(rng, max_events);
    let mut s = EventStream::new(n_types);
    let mut t = rng.range_i32(0, 20);
    for _ in 0..n_events {
        t += rng.range_i32(0, 3);
        s.push(rng.range_i32(0, n_types as i32 - 1), t);
    }
    s
}

fn random_policy(rng: &mut Rng) -> RollPolicy {
    RollPolicy {
        max_events: small_size(rng, 64),
        max_width_ticks: small_size(rng, 50) as Tick,
    }
}

/// The in-memory equivalent of a log range query: window + projection
/// over the original stream, alphabet ids preserved.
fn slice_in_memory(stream: &EventStream, q: &RangeQuery) -> EventStream {
    let mut out = EventStream::new(stream.n_types);
    for (ty, t) in stream.iter() {
        if q.t_from.is_some_and(|from| t <= from) {
            continue;
        }
        if q.t_to.is_some_and(|to| t > to) {
            continue;
        }
        if let Some(types) = &q.alphabet {
            if !types.contains(&ty) {
                continue;
            }
        }
        out.push(ty, t);
    }
    out
}

/// `(episode display, count)` — the order-insensitive shape two mining
/// runs are compared on.
type CountedShape = (String, u64);

fn mine_cpu(stream: EventStream, theta: u64) -> Result<Vec<CountedShape>, MineError> {
    if stream.is_empty() {
        return Ok(vec![]);
    }
    let mut session = Session::builder()
        .stream(stream)
        .theta(theta)
        .interval(Interval::new(0, 4))
        .strategy(Strategy::CpuSerial)
        .max_level(3)
        .build()?;
    let result = session.mine()?;
    Ok(result
        .frequent
        .iter()
        .map(|c| (c.episode.display(), c.count))
        .collect())
}

#[test]
fn ingest_seal_recover_equivalence_property() {
    let base = scratch("prop");
    let mut case_no = 0u64;
    forall("ingest recover+equivalence", 0x1065, 25, |rng| {
        case_no += 1;
        let dir = base.join(format!("case{case_no}"));
        let stream = random_stream(rng, 300);
        let policy = random_policy(rng);

        // ingest the whole stream, sealing per the random roll policy
        let mut ingestor = SpikeLog::create(&dir, stream.n_types)
            .map_err(|e| e.to_string())?
            .ingestor(policy)
            .map_err(|e| e.to_string())?;
        ingestor.append_stream(&stream).map_err(|e| e.to_string())?;
        let log = ingestor.finish().map_err(|e| e.to_string())?;
        let sealed: Vec<_> = log.segments().to_vec();
        if log.len() != stream.len() {
            return Err(format!("sealed {} of {} events", log.len(), stream.len()));
        }

        // simulate a torn tail: a partial segment file that never made
        // the manifest (crash between file write and manifest replace)
        let torn_name = format!("segment-{:06}.seg", sealed.len() as u64 + 7);
        let donor = dir.join(&sealed[0].file);
        let bytes = std::fs::read(&donor).map_err(|e| e.to_string())?;
        let cut = 1 + rng.below(bytes.len() as u64 - 1) as usize;
        std::fs::write(dir.join(&torn_name), &bytes[..cut]).map_err(|e| e.to_string())?;

        // reopen (read-only): exactly the sealed segments survive; the
        // torn tail is detected but never mined and never touched
        let log = SpikeLog::open(&dir).map_err(|e| e.to_string())?;
        if log.segments() != sealed.as_slice() {
            return Err("recovered segment set differs from the sealed set".into());
        }
        if log.recovery().torn_tails != vec![torn_name.clone()] {
            return Err(format!(
                "expected torn tail {torn_name} detected, got {:?}",
                log.recovery().torn_tails
            ));
        }
        if !dir.join(&torn_name).exists() {
            return Err("read-only open must not touch the torn tail".into());
        }
        let (all, _) = log.read_all().map_err(|e| e.to_string())?;
        if all != stream {
            return Err("read_all must reproduce the ingested stream".into());
        }

        // random range + projection queries: the materialized slice and
        // its mining result match the in-memory equivalent exactly
        for _ in 0..3 {
            let span_lo = stream.t_begin() - 2;
            let span_hi = stream.t_end() + 2;
            let a = rng.range_i32(span_lo, span_hi);
            let b = rng.range_i32(span_lo, span_hi);
            let (from, to) = (a.min(b), a.max(b));
            let mut q = RangeQuery::all().range(from, to);
            if rng.chance(0.5) {
                let keep: Vec<EventType> = (0..stream.n_types as i32)
                    .filter(|_| rng.chance(0.6))
                    .collect();
                if !keep.is_empty() {
                    q.alphabet = Some(keep);
                }
            }
            let (got, stats) = log.read(&q).map_err(|e| e.to_string())?;
            let want = slice_in_memory(&stream, &q);
            if got != want {
                return Err(format!(
                    "range ({from}, {to}] projection {:?}: log read diverges \
                     ({} vs {} events)",
                    q.alphabet,
                    got.len(),
                    want.len()
                ));
            }
            if stats.segments_read + stats.pruned_by_time + stats.pruned_by_alphabet
                != stats.segments_total
            {
                return Err("read stats must account for every segment".into());
            }
            let mined_log = mine_cpu(got, 2).map_err(|e| e.to_string())?;
            let mined_mem = mine_cpu(want, 2).map_err(|e| e.to_string())?;
            if mined_log != mined_mem {
                return Err("mining the log slice diverged from the in-memory slice".into());
            }
        }

        // attaching the writer quarantines the torn tail (bytes kept
        // aside for forensics, name freed for the next seal)
        let log = log
            .ingestor(policy)
            .map_err(|e| e.to_string())?
            .finish()
            .map_err(|e| e.to_string())?;
        if log.recovery().quarantined != vec![torn_name.clone()] {
            return Err(format!(
                "expected {torn_name} quarantined at attach, got {:?}",
                log.recovery().quarantined
            ));
        }
        if !dir.join(format!("{torn_name}.quarantined")).exists() {
            return Err("quarantined bytes must be preserved for forensics".into());
        }
        if dir.join(&torn_name).exists() {
            return Err("quarantine must free the torn segment's name".into());
        }
        if log.segments() != sealed.as_slice() {
            return Err("writer attach must not change the sealed set".into());
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn streaming_producer_feeds_the_ingestor_losslessly() {
    let dir = scratch("producer");
    let mut rng = Rng::new(42);
    let mut stream = random_stream(&mut rng, 2_000);
    while stream.span() < 50 {
        // ensure several partitions' worth of span
        let t = stream.t_end() + 1;
        stream.push(0, t);
    }
    let rx = spawn_producer_with(
        stream.clone(),
        10,
        ProducerConfig { speedup: 1e9, ..Default::default() },
    )
    .unwrap();
    let mut ingestor = SpikeLog::create(&dir, stream.n_types)
        .unwrap()
        .ingestor(RollPolicy { max_events: 64, max_width_ticks: 25 })
        .unwrap();
    let events = ingestor.ingest_partitions(rx).unwrap();
    let log = ingestor.finish().unwrap();
    assert_eq!(events, stream.len());
    assert!(log.segments().len() > 1, "several segments expected");
    let (back, _) = log.read_all().unwrap();
    assert_eq!(back, stream, "partition-fed ingest must be lossless and ordered");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_sealed_segments_surface_as_typed_errors() {
    let dir = scratch("corrupt");
    let stream = EventStream::from_pairs((0..200).map(|i| (i % 3, i)).collect(), 3);
    let mut ingestor = SpikeLog::create(&dir, 3)
        .unwrap()
        .ingestor(RollPolicy { max_events: 50, max_width_ticks: 1_000 })
        .unwrap();
    ingestor.append_stream(&stream).unwrap();
    let log = ingestor.finish().unwrap();
    let victim = dir.join(&log.segments()[1].file);
    drop(log);

    // flip one event byte: structure (length, magics) stays valid, so
    // open succeeds — but reading the segment must fail the checksum
    let mut bytes = std::fs::read(&victim).unwrap();
    bytes[25] ^= 0xFF;
    std::fs::write(&victim, &bytes).unwrap();
    let log = SpikeLog::open(&dir).unwrap();
    let err = log.read_all().err().expect("bit rot must not mine silently");
    assert!(matches!(err, MineError::Corrupt { .. }), "{err}");

    // truncate the same sealed segment: now even open must refuse — the
    // manifest names data that is structurally gone
    std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();
    let err = SpikeLog::open(&dir).err().expect("torn sealed segment must fail open");
    assert!(matches!(err, MineError::Corrupt { .. }), "{err}");

    // remove it entirely: typed I/O error naming the path
    std::fs::remove_file(&victim).unwrap();
    let err = SpikeLog::open(&dir).err().expect("missing sealed segment must fail open");
    assert!(matches!(err, MineError::Io { .. } | MineError::Corrupt { .. }), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn histogram_tampering_is_caught_at_open() {
    // alphabet-projection pruning trusts the footer histogram without
    // reading the event columns, so the manifest carries a digest of it:
    // a flipped hist byte must fail open, not silently drop events from
    // projected queries
    let dir = scratch("hist");
    let mut ingestor = SpikeLog::create(&dir, 3).unwrap().ingestor(RollPolicy::default()).unwrap();
    for t in 0..50 {
        ingestor.append(t % 3, t).unwrap();
    }
    let log = ingestor.finish().unwrap();
    let victim = dir.join(&log.segments()[0].file);
    drop(log);

    let mut bytes = std::fs::read(&victim).unwrap();
    let hist_off = 20 + 8 * 50 + 8; // header + event columns + t_min/t_max
    bytes[hist_off] ^= 0xFF;
    std::fs::write(&victim, &bytes).unwrap();
    let err = SpikeLog::open(&dir).err().expect("tampered histogram must fail open");
    assert!(matches!(err, MineError::Corrupt { .. }), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ingestor_enforces_order_and_alphabet() {
    let dir = scratch("invariants");
    let mut ingestor = SpikeLog::create(&dir, 2)
        .unwrap()
        .ingestor(RollPolicy::default())
        .unwrap();
    ingestor.append(0, 10).unwrap();
    let err = ingestor.append(1, 9).err().unwrap();
    assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
    let err = ingestor.append(5, 11).err().unwrap();
    assert!(matches!(err, MineError::OutOfAlphabet { type_id: 5, n_types: 2 }), "{err}");
    // equal times are fine (simultaneous spikes on different electrodes)
    ingestor.append(1, 10).unwrap();

    // order is enforced across seals too: after finishing and reopening,
    // the floor is the last sealed time
    let log = ingestor.finish().unwrap();
    let mut ingestor = SpikeLog::open(log.dir()).unwrap().ingestor(RollPolicy::default()).unwrap();
    let err = ingestor.append(0, 3).err().unwrap();
    assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
    ingestor.append(0, 10).unwrap();
    let log = ingestor.finish().unwrap();
    assert_eq!(log.len(), 3);
    std::fs::remove_dir_all(log.dir()).ok();
}

#[test]
fn create_refuses_to_clobber_and_open_requires_a_manifest() {
    let dir = scratch("clobber");
    let log = SpikeLog::create(&dir, 2).unwrap();
    drop(log);
    let err = SpikeLog::create(&dir, 2).err().unwrap();
    assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");

    let empty = scratch("no_manifest");
    std::fs::create_dir_all(&empty).unwrap();
    let err = SpikeLog::open(&empty).err().unwrap();
    assert!(matches!(err, MineError::Io { .. }), "{err}");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&empty).ok();
}

#[test]
fn log_scheme_mines_through_session_and_registry() {
    let dir = scratch("scheme");
    let stream = EventStream::from_pairs((0..400).map(|i| (i % 4, i / 2)).collect(), 4);
    let mut ingestor = SpikeLog::create(&dir, 4)
        .unwrap()
        .ingestor(RollPolicy { max_events: 100, max_width_ticks: 10_000 })
        .unwrap();
    ingestor.append_stream(&stream).unwrap();
    drop(ingestor.finish().unwrap());

    let spec = format!("log:{}", dir.display());
    let (resolved, tag) = datasets::resolve(&spec, 7).unwrap();
    assert_eq!(resolved, stream);
    assert_eq!(tag, spec);

    // the file: scheme round-trips through events::io's typed wrappers
    let bin = dir.join("export.bin");
    io::save_binary(&stream, &bin).unwrap();
    let file_spec = format!("file:{}", bin.display());
    let (resolved, _) = datasets::resolve(&file_spec, 7).unwrap();
    assert_eq!(resolved, stream);

    // and both drive a Session end to end (dataset default interval
    // falls back to the generic band for path-backed specs)
    let mut session = Session::builder()
        .dataset(&spec)
        .theta(5)
        .strategy(Strategy::CpuSerial)
        .max_level(2)
        .build()
        .unwrap();
    let via_log = session.mine().unwrap();
    let mut session = Session::builder()
        .stream(stream)
        .theta(5)
        .interval(Interval::new(2, 10))
        .strategy(Strategy::CpuSerial)
        .max_level(2)
        .build()
        .unwrap();
    let direct = session.mine().unwrap();
    assert_eq!(via_log.frequent, direct.frequent);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mine_service_serves_log_backed_scenarios_identically() {
    // the end of the acceptance property: a log-backed loadgen scenario
    // set, served through MineService, matches direct Session mining
    let dir = scratch("serve");
    let mut rng = Rng::new(0xFEED);
    let mut pairs = vec![];
    let mut t = 0;
    for _ in 0..3_000 {
        t += rng.range_i32(1, 3);
        pairs.push((rng.range_i32(0, 5), t));
    }
    let stream = EventStream::from_pairs(pairs, 6);
    let mut ingestor = SpikeLog::create(&dir, 6)
        .unwrap()
        .ingestor(RollPolicy { max_events: 512, max_width_ticks: 2_000 })
        .unwrap();
    ingestor.append_stream(&stream).unwrap();
    drop(ingestor.finish().unwrap());

    let lg = LoadGenConfig {
        clients: 2,
        requests_per_client: 6,
        base_dataset: Some(format!("log:{}", dir.display())),
        distinct_pool: 4,
        distinct_events: 400,
        window_ticks: 1_500,
        max_level: 3,
        ..LoadGenConfig::default()
    };
    let workload = Workload::build(&lg).unwrap();
    // hot/sweep/sliding scenarios all run off the recorded stream
    assert_eq!(*workload.hot[0].stream, stream);
    let total_window_events: usize = workload.sliding.iter().map(|q| q.stream.len()).sum();
    assert_eq!(total_window_events, stream.len());

    let service = MineService::start(ServiceConfig {
        workers: 2,
        strategy: Strategy::CpuSerial,
        ..ServiceConfig::default()
    })
    .unwrap();
    for (i, q) in workload.all().enumerate() {
        let served = service.submit(q.clone()).unwrap().wait().unwrap();
        let mut session = Session::builder()
            .stream((*q.stream).clone())
            .theta(q.theta)
            .intervals(q.intervals.clone())
            .max_level(q.max_level)
            .strategy(Strategy::CpuSerial)
            .build()
            .unwrap();
        let direct = session.mine().unwrap();
        assert_eq!(served.frequent, direct.frequent, "query {i}: counts diverge");
    }
    let metrics = service.shutdown();
    assert_eq!(metrics.failed, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn range_pruning_skips_segment_io() {
    let dir = scratch("prune");
    let stream = EventStream::from_pairs((0..4_000).map(|i| (i % 5, i)).collect(), 5);
    let mut ingestor = SpikeLog::create(&dir, 5)
        .unwrap()
        .ingestor(RollPolicy { max_events: 250, max_width_ticks: 100_000 })
        .unwrap();
    ingestor.append_stream(&stream).unwrap();
    let log = ingestor.finish().unwrap();
    assert_eq!(log.segments().len(), 16);

    let (got, stats) = log.read_range(1_000, 1_200).unwrap();
    assert_eq!(got, stream.window(1_000, 1_200));
    assert!(
        stats.pruned_by_time >= 13,
        "a 200-tick range must prune most of 16 segments, pruned {}",
        stats.pruned_by_time
    );
    assert!(stats.segments_read <= 3);

    // projection pruning: type 4 never fires in a crafted second log
    let dir2 = scratch("prune_alpha");
    let mut ingestor = SpikeLog::create(&dir2, 5)
        .unwrap()
        .ingestor(RollPolicy { max_events: 100, max_width_ticks: 100_000 })
        .unwrap();
    // first half fires types {0,1}, second half {2,3}
    for i in 0..200 {
        ingestor.append(if i < 100 { i % 2 } else { 2 + i % 2 }, i).unwrap();
    }
    let log2 = ingestor.finish().unwrap();
    let (only23, stats) = log2.read(&RangeQuery::all().types(vec![2, 3])).unwrap();
    assert!(only23.types.iter().all(|&ty| ty == 2 || ty == 3));
    assert_eq!(only23.len(), 100);
    assert!(stats.pruned_by_alphabet >= 1, "histogram pruning must skip {{0,1}}-only segments");
    let err = log2.read(&RangeQuery::all().types(vec![9])).err().unwrap();
    assert!(matches!(err, MineError::OutOfAlphabet { type_id: 9, n_types: 5 }), "{err}");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

#[test]
fn watch_log_service_publishes_commits_to_its_own_subscribers() {
    // satellite of the live-mining story: a service configured with
    // `watch_log` tails the log directory itself — subscribers on the
    // `log:<dir>` topic receive CommitUpdates with no external publisher
    let dir = scratch("watchlog");
    let mut ingestor = SpikeLog::create(&dir, 4)
        .unwrap()
        .ingestor(RollPolicy { max_events: 64, max_width_ticks: 100_000 })
        .unwrap();
    let mut t = 0;
    let mut push = |ingestor: &mut episodes_gpu::ingest::Ingestor, n: usize, t: &mut i32| {
        for i in 0..n {
            *t += 1 + (i as i32 % 2);
            ingestor.append(i as i32 % 4, *t).unwrap();
        }
    };
    // seal some history before the service starts
    push(&mut ingestor, 200, &mut t);

    let mut wl = WatchLogConfig::new(&dir, IncrementalConfig::new(3, vec![Interval::new(0, 6)]));
    wl.poll_interval = std::time::Duration::from_millis(20);
    let topic = wl.resolved_topic();
    assert_eq!(topic, format!("log:{}", dir.display()), "topic follows the log: spec");
    let service = MineService::start(ServiceConfig {
        workers: 1,
        strategy: Strategy::CpuSerial,
        watch_log: Some(wl),
        ..ServiceConfig::default()
    })
    .unwrap();
    let sub = service.subscribe(SubscribeQuery::new("live", topic)).unwrap();

    // seal more segments while the watcher is live: these commits can
    // only reach the subscriber through the service's own watcher thread
    push(&mut ingestor, 200, &mut t);
    drop(ingestor.finish().unwrap());

    let update = sub
        .recv_timeout(std::time::Duration::from_secs(20))
        .expect("the watcher must publish a commit for a newly sealed segment");
    assert!(update.seq >= 1);
    let m = service.shutdown();
    assert!(m.updates_published >= 1, "publishes must be accounted: {}", m.report());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn watch_log_on_a_missing_directory_fails_service_start() {
    let dir = scratch("watchlog_missing"); // never created
    let wl = WatchLogConfig::new(
        &dir,
        IncrementalConfig::new(3, vec![Interval::new(0, 6)]),
    );
    assert!(
        MineService::start(ServiceConfig { watch_log: Some(wl), ..ServiceConfig::default() })
            .is_err(),
        "a watch dir that cannot be opened must fail start, not die silently"
    );
}

#[test]
fn arc_streams_flow_from_log_reads_into_queries() {
    // glue check: a log-read stream is a normal EventStream; wrapping it
    // for the serve layer needs no copying gymnastics
    let dir = scratch("arc");
    let mut ingestor = SpikeLog::create(&dir, 2).unwrap().ingestor(RollPolicy::default()).unwrap();
    for t in 0..50 {
        ingestor.append(t % 2, t).unwrap();
    }
    let log = ingestor.finish().unwrap();
    let (stream, _) = log.read_all().unwrap();
    let q = episodes_gpu::serve::Query::new(Arc::new(stream), 2, vec![Interval::new(0, 3)]);
    assert!(q.validate().is_ok());
    std::fs::remove_dir_all(&dir).ok();
}
