//! The stream/ acceptance criteria: at every commit point the incremental
//! frequent-episode set (episodes, counts, order) equals a cold batch
//! re-mine of the exact window the miner holds — across randomized segment
//! widths, thetas near frequency boundaries, sliding-window sizes, and
//! bounded-K counting — plus the deterministic subscription-diff behavior
//! of the serve/ push path (registry caps, bounded buffers, shutdown).

use std::sync::Arc;
use std::time::Duration;

use episodes_gpu::backend::sharded::ShardedBackend;
use episodes_gpu::coordinator::Strategy;
use episodes_gpu::episodes::{CountedEpisode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::serve::{MineService, ServiceConfig, SubscribeQuery};
use episodes_gpu::stream::{CommitUpdate, IncrementalConfig, IncrementalMiner};
use episodes_gpu::util::rng::Rng;
use episodes_gpu::{MineError, Session};

/// Cold one-pass serial mine of `window` — the exact batch reference the
/// incremental engine must match commit for commit.
fn cold_mine(
    window: &EventStream,
    theta: u64,
    iv: Interval,
    max_level: usize,
) -> Vec<CountedEpisode> {
    let mut session = Session::builder()
        .stream(window.clone())
        .theta(theta)
        .interval(iv)
        .strategy(Strategy::CpuSerial)
        .one_pass()
        .max_level(max_level)
        .build()
        .unwrap();
    session.mine().unwrap().frequent
}

/// The bounded-K batch reference: a sharded engine with K-bounded
/// occurrence lists (counts equal `serial::count_a1_bounded`), one-pass.
fn cold_mine_bounded(
    window: &EventStream,
    theta: u64,
    iv: Interval,
    max_level: usize,
    k: usize,
) -> Vec<CountedEpisode> {
    let mut session = Session::builder()
        .stream(window.clone())
        .theta(theta)
        .interval(iv)
        .backend(Box::new(ShardedBackend::new(2).with_k(k)))
        .one_pass()
        .max_level(max_level)
        .build()
        .unwrap();
    session.mine().unwrap().frequent
}

/// One random segment: `len` events with 1-4 tick gaps starting after `t`.
fn random_segment(rng: &mut Rng, t: &mut i32, len: usize, n_types: usize) -> EventStream {
    let mut pairs = Vec::with_capacity(len);
    for _ in 0..len {
        *t += rng.range_i32(1, 4);
        pairs.push((rng.range_i32(0, n_types as i32 - 1), *t));
    }
    EventStream::from_pairs(pairs, n_types)
}

#[test]
fn incremental_equals_cold_batch_mine_at_every_commit() {
    // Randomized sweep: segment widths vary per push (including 1-event
    // slivers), windows slide across segment boundaries, and theta is
    // drawn small enough to sit near the frequency boundary of a short
    // window — the regime where a stale count or a missed retire flips an
    // episode across theta and diverges the frontier.
    for seed in 0..8u64 {
        let mut rng = Rng::new(0xA11CE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let n_types = 2 + (seed % 3) as usize;
        let theta = 2 + seed % 3;
        let window_segments = 2 + (seed % 3) as usize;
        let iv = Interval::new(0, 4 + (seed % 3) as i32);
        let cfg = IncrementalConfig::new(theta, vec![iv])
            .max_level(3)
            .window_segments(window_segments);
        let mut miner = IncrementalMiner::new(n_types, cfg).unwrap();
        let mut t = 0i32;
        for step in 0..10 {
            let len = 1 + rng.below(40) as usize;
            let seg = random_segment(&mut rng, &mut t, len, n_types);
            let update = miner.push_segment(seg).unwrap();
            let window = miner.window_stream();
            let batch = cold_mine(&window, theta, iv, 3);
            assert_eq!(
                *update.frequent, batch,
                "seed {seed} step {step}: incremental commit diverged from \
                 batch re-mine of ({}, {}]",
                update.window_start, update.window_end
            );
            assert_eq!(update.window_events, window.len(), "seed {seed} step {step}");
        }
    }
}

#[test]
fn bounded_k_incremental_matches_bounded_k_batch() {
    // With K-bounded occurrence slots the counts are a different (still
    // deterministic) semantics — the incremental path must implement
    // exactly the batch bounded-K semantics, not approximate it.
    for seed in 0..4u64 {
        let mut rng = Rng::new(0xB07B5 ^ seed);
        let n_types = 3;
        let theta = 2;
        let k = 1 + (seed % 2) as usize;
        let iv = Interval::new(0, 5);
        let cfg = IncrementalConfig::new(theta, vec![iv])
            .max_level(3)
            .window_segments(3)
            .bounded_k(k);
        let mut miner = IncrementalMiner::new(n_types, cfg).unwrap();
        let mut t = 0i32;
        for step in 0..8 {
            let len = 5 + rng.below(25) as usize;
            let seg = random_segment(&mut rng, &mut t, len, n_types);
            let update = miner.push_segment(seg).unwrap();
            let batch = cold_mine_bounded(&miner.window_stream(), theta, iv, 3, k);
            assert_eq!(
                *update.frequent, batch,
                "seed {seed} step {step} K={k}: bounded-K divergence"
            );
        }
    }
}

#[test]
fn diff_stream_replays_to_the_final_frequent_set() {
    // The push path's contract: applying entered/left/count-changed diffs
    // in commit order reconstructs each commit's frequent set — that is
    // what makes pushing diffs instead of full sets sound.
    let mut rng = Rng::new(0xD1FF);
    let iv = Interval::new(0, 6);
    let cfg = IncrementalConfig::new(3, vec![iv]).max_level(2).window_segments(3);
    let mut miner = IncrementalMiner::new(3, cfg).unwrap();
    let mut t = 0i32;
    let mut view: Vec<CountedEpisode> = vec![];
    for _ in 0..8 {
        let len = 10 + rng.below(20) as usize;
        let seg = random_segment(&mut rng, &mut t, len, 3);
        let update = miner.push_segment(seg).unwrap();
        // apply the diff to the view: drop left, upsert entered/changed
        view.retain(|c| !update.diff.left.iter().any(|l| l.episode == c.episode));
        for e in &update.diff.entered {
            view.push(e.clone());
        }
        for ch in &update.diff.count_changed {
            let slot = view
                .iter_mut()
                .find(|c| c.episode == ch.episode)
                .expect("count_changed episode must already be in the view");
            assert_eq!(slot.count, ch.previous, "stale previous count in diff");
            slot.count = ch.current;
        }
        let mut want: Vec<CountedEpisode> = (*update.frequent).clone();
        let key = |c: &CountedEpisode| format!("{:?}", c.episode);
        view.sort_by_key(&key);
        want.sort_by_key(&key);
        assert_eq!(view, want, "diff replay diverged at commit {}", update.seq);
    }
}

#[test]
fn frontier_move_regenerates_candidates_and_stays_exact() {
    // Arena-cached candidate generation: while the per-level frequency
    // frontier is stable, a commit reuses the cached candidate blocks
    // (candidate_regens == 0); the moment the frontier moves, the affected
    // levels regenerate. Either way the frequent set must equal a cold
    // batch re-mine at every single commit. Six segments of a 0->1 pattern
    // hold the frontier still, then a 1->2 pattern pushes type 2 over
    // theta and moves it.
    let iv = Interval::new(0, 6);
    let theta = 2;
    let cfg = IncrementalConfig::new(theta, vec![iv]).max_level(3).window_segments(3);
    let mut miner = IncrementalMiner::new(3, cfg).unwrap();
    let seg = |base: i32, a: i32, b: i32| {
        let pairs: Vec<(i32, i32)> =
            (0..3).flat_map(|i| [(a, base + 4 * i + 1), (b, base + 4 * i + 3)]).collect();
        EventStream::from_pairs(pairs, 3)
    };
    let mut saw_cached = false;
    for step in 0..10 {
        let (a, b) = if step < 6 { (0, 1) } else { (1, 2) };
        let update = miner.push_segment(seg(20 * step, a, b)).unwrap();
        let batch = cold_mine(&miner.window_stream(), theta, iv, 3);
        assert_eq!(*update.frequent, batch, "step {step}: diverged from batch re-mine");
        if (3..6).contains(&step) && update.stats.candidate_regens == 0 {
            saw_cached = true;
        }
        if step == 6 {
            assert!(update.stats.candidate_regens > 0, "frontier moved, cache must invalidate");
        }
    }
    assert!(saw_cached, "steady-state commits must reuse cached candidate blocks");
}

// ---- subscription push path (deterministic via the paused pool) ----

fn paused_service(max_subs: usize) -> MineService {
    MineService::start_paused(ServiceConfig {
        workers: 1,
        strategy: Strategy::CpuSerial,
        max_subscriptions_per_tenant: max_subs,
        ..ServiceConfig::default()
    })
    .unwrap()
}

/// A real commit sequence to publish: three commits over a 2-segment
/// window whose diffs are non-trivial (episodes enter, change, leave).
fn commit_sequence() -> Vec<CommitUpdate> {
    let iv = Interval::new(0, 6);
    let cfg = IncrementalConfig::new(2, vec![iv]).max_level(2).window_segments(2);
    let mut miner = IncrementalMiner::new(2, cfg).unwrap();
    let segs = [
        vec![(0, 1), (1, 3), (0, 5), (1, 7)],
        vec![(0, 11), (1, 13), (0, 15), (1, 17)],
        vec![(0, 21), (0, 23), (0, 25), (0, 27)],
    ];
    segs.iter()
        .map(|pairs| miner.push_segment(EventStream::from_pairs(pairs.clone(), 2)).unwrap())
        .collect()
}

#[test]
fn subscribers_receive_every_commit_in_order_as_diffs() {
    let service = paused_service(4);
    let sub = service.subscribe(SubscribeQuery::new("tenant-a", "live")).unwrap();
    let other_topic = service.subscribe(SubscribeQuery::new("tenant-a", "other")).unwrap();
    let updates = commit_sequence();
    for u in &updates {
        let delivered = service.publish("live", u.clone());
        assert_eq!(delivered, 1, "exactly the matching-topic subscriber");
    }
    for want in &updates {
        let got = sub.recv_timeout(Duration::from_secs(5)).expect("pushed commit");
        assert_eq!(got.seq, want.seq, "commits arrive in publish order");
        assert_eq!(got.frequent, want.frequent);
        assert_eq!(got.diff.entered, want.diff.entered);
        assert_eq!(got.diff.left, want.diff.left);
        assert_eq!(got.diff.count_changed, want.diff.count_changed);
    }
    assert!(sub.try_recv().is_none(), "no phantom commits");
    assert!(other_topic.try_recv().is_none(), "topics are isolated");
    let m = service.metrics();
    assert_eq!(m.subscriptions_active, 2);
    assert_eq!(m.updates_published, updates.len() as u64);
    assert_eq!(m.updates_dropped, 0);
    service.resume();
    service.shutdown();
    assert!(sub.is_closed(), "shutdown closes subscriptions");
    assert!(sub.recv_timeout(Duration::from_millis(10)).is_none());
}

#[test]
fn per_tenant_subscription_cap_is_enforced_and_freed_on_drop() {
    let service = paused_service(2);
    let s1 = service.subscribe(SubscribeQuery::new("t", "live")).unwrap();
    let _s2 = service.subscribe(SubscribeQuery::new("t", "live")).unwrap();
    let err = service.subscribe(SubscribeQuery::new("t", "live")).err().unwrap();
    assert!(
        matches!(err, MineError::Busy { queue_depth: 2, capacity: 2 }),
        "cap exceeded must be typed Busy: {err}"
    );
    // other tenants are unaffected by t's cap
    let _other = service.subscribe(SubscribeQuery::new("u", "live")).unwrap();
    // dropping a subscription frees its slot
    drop(s1);
    let _s3 = service.subscribe(SubscribeQuery::new("t", "live")).unwrap();
    let m = service.metrics();
    assert_eq!(m.subscriptions_rejected, 1);
    assert_eq!(m.subscriptions_active, 3);
    service.resume();
    service.shutdown();
}

#[test]
fn slow_subscriber_buffer_drops_oldest_keeps_newest() {
    let service = paused_service(4);
    let sub = service
        .subscribe(SubscribeQuery::new("slow", "live").buffer(1))
        .unwrap();
    let updates = commit_sequence();
    for u in &updates {
        service.publish("live", u.clone());
    }
    assert_eq!(sub.backlog(), 1, "buffer of 1 holds only the newest commit");
    let got = sub.try_recv().expect("newest commit retained");
    assert_eq!(got.seq, updates.last().unwrap().seq);
    assert!(sub.try_recv().is_none());
    let m = service.metrics();
    assert_eq!(m.updates_dropped, (updates.len() - 1) as u64);
    service.resume();
    service.shutdown();
}

#[test]
fn loadgen_live_feed_publishes_and_subscribers_drain() {
    // End to end through the load generator: publisher thread drives the
    // incremental miner over the sliding partitions, subscriber threads
    // drain the pushed commits while query load runs.
    use episodes_gpu::serve::loadgen::{self, LoadGenConfig, Workload};
    let cfg = LoadGenConfig {
        clients: 2,
        requests_per_client: 4,
        base_events: 1_500,
        distinct_pool: 4,
        distinct_events: 300,
        window_ticks: 700,
        max_level: 3,
        subscribers: 2,
        ..LoadGenConfig::default()
    };
    let workload = Workload::build(&cfg).unwrap();
    let service = MineService::start(ServiceConfig {
        workers: 2,
        strategy: Strategy::CpuSerial,
        ..ServiceConfig::default()
    })
    .unwrap();
    let report = loadgen::run(&service, &workload, &cfg);
    service.shutdown();
    assert_eq!(report.updates_published, workload.sliding.len() as u64);
    // both subscribers were registered before the publisher started and
    // drain until the feed ends: nothing may be lost short of buffer
    // drops, and these buffers (64) far exceed the commit count
    assert_eq!(report.updates_received, 2 * report.updates_published);
    assert_eq!(report.errors, 0);
    let json = report.to_json();
    assert!(
        json.contains("\"updates_published\":") && json.contains("\"updates_received\":"),
        "{json}"
    );
}
