//! Cross-language fixtures: identical literals live in
//! `python/tests/test_fixtures.py`. If either implementation drifts from
//! the paper's semantics, the two suites diverge and one side fails.

use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::mining::serial;

const EV: [i32; 60] = [
    5, 1, 2, 3, 4, 5, 0, 2, 0, 2, 0, 1, 4, 4, 3, 1, 1, 4, 4, 0, 5, 2, 0, 1, 2, 3, 2, 4, 3, 5, 1,
    4, 5, 0, 5, 1, 5, 3, 2, 2, 5, 2, 1, 3, 0, 2, 4, 3, 4, 4, 3, 3, 5, 5, 4, 2, 1, 4, 3, 2,
];
const TM: [i32; 60] = [
    2, 5, 5, 6, 9, 9, 9, 12, 13, 14, 17, 17, 20, 20, 21, 22, 22, 24, 27, 28, 29, 31, 34, 35, 38,
    41, 44, 45, 46, 48, 48, 48, 49, 49, 52, 53, 56, 57, 59, 62, 64, 64, 64, 64, 64, 64, 65, 66,
    66, 66, 66, 66, 69, 69, 72, 75, 75, 77, 77, 77,
];

fn fixture_stream() -> EventStream {
    let pairs = EV.iter().copied().zip(TM.iter().copied()).collect();
    EventStream::from_pairs(pairs, 6)
}

struct Case {
    types: &'static [i32],
    tlow: &'static [i32],
    thigh: &'static [i32],
    a1: u64,
    a2: u64,
}

const CASES: [Case; 4] = [
    Case { types: &[1, 1, 2], tlow: &[0, 0], thigh: &[10, 10], a1: 2, a2: 2 },
    Case { types: &[5, 0, 3, 2], tlow: &[0, 0, 0], thigh: &[12, 12, 12], a1: 2, a2: 3 },
    Case { types: &[4, 3], tlow: &[0], thigh: &[3], a1: 3, a2: 5 },
    Case { types: &[2, 0, 1], tlow: &[1, 0], thigh: &[9, 12], a1: 4, a2: 4 },
];

fn episode(c: &Case) -> Episode {
    let ivs = c
        .tlow
        .iter()
        .zip(c.thigh)
        .map(|(&l, &h)| Interval::new(l, h))
        .collect();
    Episode::new(c.types.to_vec(), ivs)
}

#[test]
fn serial_a1_matches_python_fixtures() {
    let s = fixture_stream();
    for c in &CASES {
        assert_eq!(serial::count_a1(&episode(c), &s), c.a1, "types {:?}", c.types);
    }
}

#[test]
fn bounded_a1_k8_matches_python_fixtures() {
    let s = fixture_stream();
    for c in &CASES {
        assert_eq!(serial::count_a1_bounded(&episode(c), &s, 8), c.a1, "types {:?}", c.types);
    }
}

#[test]
fn serial_a2_matches_python_fixtures() {
    let s = fixture_stream();
    for c in &CASES {
        assert_eq!(serial::count_a2(&episode(c), &s), c.a2, "types {:?}", c.types);
    }
}

#[test]
fn theorem_5_1_holds_on_fixtures() {
    for c in &CASES {
        assert!(c.a2 >= c.a1);
    }
}
