//! Integration tests over the PJRT runtime: the accelerated counting path
//! (AOT Pallas kernels) against the CPU references, over every artifact.
//!
//! Requires `make artifacts` plus real PJRT bindings. When the runtime is
//! unavailable (no artifacts, or the stub `xla` crate is linked) every
//! test skips with a notice rather than failing — the CPU-path coverage
//! lives in `miner_e2e.rs` / `session_api.rs` and always runs.

use std::rc::Rc;

use episodes_gpu::backend::two_pass::TwoPassBackend;
use episodes_gpu::backend::{self, CountBackend};
use episodes_gpu::coordinator::Strategy;
use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::mining::serial;
use episodes_gpu::runtime::{exec, Runtime};
use episodes_gpu::util::rng::Rng;

fn open_rt() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn open_shared_rt() -> Option<Rc<Runtime>> {
    open_rt().map(Rc::new)
}

/// Exact counts under `strategy` via the same engine construction
/// `Session` uses (`backend::for_strategy`).
fn count_with(
    rt: &Rc<Runtime>,
    strategy: Strategy,
    episodes: &[Episode],
    stream: &EventStream,
) -> Vec<u64> {
    let mut be = backend::for_strategy(strategy, Some(Rc::clone(rt)), 4)
        .expect("engine construction");
    be.count(episodes, stream).expect("count").counts
}

fn gen_stream(rng: &mut Rng, n_events: usize, n_types: i32) -> EventStream {
    let mut pairs = Vec::with_capacity(n_events);
    let mut t = 0;
    for _ in 0..n_events {
        t += rng.range_i32(0, 4);
        pairs.push((rng.range_i32(0, n_types - 1), t));
    }
    EventStream::from_pairs(pairs, n_types as usize)
}

fn gen_episodes(rng: &mut Rng, count: usize, n: usize, n_types: i32) -> Vec<Episode> {
    (0..count)
        .map(|_| {
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, n_types - 1)).collect();
            let ivs: Vec<Interval> = (0..n - 1)
                .map(|_| {
                    let lo = rng.range_i32(0, 3);
                    Interval::new(lo, lo + rng.range_i32(1, 10))
                })
                .collect();
            Episode::new(types, ivs)
        })
        .collect()
}

#[test]
fn a1_artifacts_match_cpu_reference_all_sizes() {
    let Some(rt) = open_rt() else { return };
    let k = rt.manifest().k_slots;
    let mut rng = Rng::new(0xA1);
    let stream = gen_stream(&mut rng, 3000, 8);
    for n in rt.manifest().n_min..=rt.manifest().n_max {
        let eps = gen_episodes(&mut rng, 40, n, 8);
        let got = exec::count_a1(&rt, &eps, &stream).unwrap();
        for (i, ep) in eps.iter().enumerate() {
            let want = serial::count_a1_bounded(ep, &stream, k);
            assert_eq!(got[i], want, "n={n} ep {}", ep.display());
        }
    }
}

#[test]
fn a2_artifacts_match_cpu_reference_all_sizes() {
    let Some(rt) = open_rt() else { return };
    let mut rng = Rng::new(0xA2);
    let stream = gen_stream(&mut rng, 3000, 8);
    for n in rt.manifest().n_min..=rt.manifest().n_max {
        let eps = gen_episodes(&mut rng, 40, n, 8);
        let got = exec::count_a2(&rt, &eps, &stream).unwrap();
        for (i, ep) in eps.iter().enumerate() {
            let want = serial::count_a2(ep, &stream);
            assert_eq!(got[i], want, "n={n} ep {}", ep.display());
        }
    }
}

#[test]
fn chunk_carry_spans_multiple_chunks() {
    // stream longer than one chunk: counts must match the single-pass CPU
    // reference exactly (state carried across chunk boundaries)
    let Some(rt) = open_rt() else { return };
    let c = rt.manifest().c_chunk;
    let k = rt.manifest().k_slots;
    let mut rng = Rng::new(0xCC);
    let stream = gen_stream(&mut rng, 3 * c + 17, 6);
    let eps = gen_episodes(&mut rng, 16, 3, 6);
    let got = exec::count_a1(&rt, &eps, &stream).unwrap();
    for (i, ep) in eps.iter().enumerate() {
        assert_eq!(got[i], serial::count_a1_bounded(ep, &stream, k), "{}", ep.display());
    }
}

#[test]
fn batching_pads_beyond_m_episodes() {
    let Some(rt) = open_rt() else { return };
    let m = rt.manifest().m_episodes;
    let mut rng = Rng::new(0xBB);
    let stream = gen_stream(&mut rng, 1000, 5);
    let eps = gen_episodes(&mut rng, m + 37, 2, 5);
    let got = exec::count_a2(&rt, &eps, &stream).unwrap();
    assert_eq!(got.len(), eps.len());
    for (i, ep) in eps.iter().enumerate() {
        assert_eq!(got[i], serial::count_a2(ep, &stream), "{}", ep.display());
    }
}

#[test]
fn mapconcat_kernel_equals_cpu_map_and_serial_count() {
    let Some(rt) = open_rt() else { return };
    let mf = *rt.manifest();
    let mut rng = Rng::new(0x3C);
    let stream = gen_stream(&mut rng, 20_000, 6);
    let eps = gen_episodes(&mut rng, 8, 3, 6);
    let t0 = stream.t_begin() - 1;
    let t1 = stream.t_end();
    let span = (t1 - t0) as i64;
    let p = mf.mc_segments as i64;
    let taus: Vec<i32> =
        (0..p).map(|i| (t0 as i64 + span * i / p) as i32).chain([t1]).collect();

    let got = exec::mapcat_map(&rt, &eps, &stream, &taus).unwrap();
    for (j, ep) in eps.iter().enumerate() {
        // kernel Map == CPU Map, tuple for tuple
        let want = serial::mapcat_map(ep, &stream, &taus, mf.k_slots);
        let got_t: Vec<Vec<(i32, u64, i32)>> = got[j].clone();
        assert_eq!(got_t, want, "episode {}", ep.display());
    }
}

#[test]
fn backend_strategies_agree() {
    let Some(rt) = open_shared_rt() else { return };
    let mut rng = Rng::new(0x57);
    let stream = gen_stream(&mut rng, 8000, 6);
    let eps = gen_episodes(&mut rng, 24, 3, 6);
    let cpu = count_with(&rt, Strategy::CpuSerial, &eps, &stream);
    let ptpe = count_with(&rt, Strategy::PtpeA1, &eps, &stream);
    let hybrid = count_with(&rt, Strategy::Hybrid, &eps, &stream);
    let par = count_with(&rt, Strategy::CpuParallel, &eps, &stream);
    assert_eq!(cpu, ptpe);
    assert_eq!(cpu, hybrid);
    assert_eq!(cpu, par);
}

#[test]
fn backend_mapconcat_agrees_or_falls_back() {
    let Some(rt) = open_shared_rt() else { return };
    let mut rng = Rng::new(0x58);
    let stream = gen_stream(&mut rng, 30_000, 6);
    let eps = gen_episodes(&mut rng, 8, 4, 6);
    let mut mc_be = backend::for_strategy(Strategy::MapConcat, Some(Rc::clone(&rt)), 4).unwrap();
    let report = mc_be.count(&eps, &stream).unwrap();
    let cpu = count_with(&rt, Strategy::CpuSerial, &eps, &stream);
    assert_eq!(cpu, report.counts, "metrics: {}", report.metrics.report());
}

#[test]
fn two_pass_is_exact_at_threshold() {
    let Some(rt) = open_shared_rt() else { return };
    let mut rng = Rng::new(0x2B);
    let stream = gen_stream(&mut rng, 6000, 5);
    let eps = gen_episodes(&mut rng, 64, 3, 5);
    let theta = 10;
    let inner = backend::for_strategy(Strategy::Hybrid, Some(Rc::clone(&rt)), 4).unwrap();
    let (out, _metrics) = TwoPassBackend::new(inner, theta).run(&eps, &stream).unwrap();
    for (i, ep) in eps.iter().enumerate() {
        let exact = serial::count_a1_bounded(ep, &stream, rt.manifest().k_slots);
        // frequency decision must be exact
        assert_eq!(out.counts[i] >= theta, exact >= theta, "{}", ep.display());
        // survivors carry exact counts
        if out.relaxed_counts[i] >= theta {
            assert_eq!(out.counts[i], exact, "{}", ep.display());
        }
        // Theorem 5.1 on the kernel path
        assert!(out.relaxed_counts[i] >= exact);
    }
}

#[test]
fn mixed_size_batches_route_correctly() {
    let Some(rt) = open_shared_rt() else { return };
    let mut rng = Rng::new(0x33);
    let stream = gen_stream(&mut rng, 4000, 5);
    let mut eps = gen_episodes(&mut rng, 10, 2, 5);
    eps.extend(gen_episodes(&mut rng, 10, 4, 5));
    eps.push(Episode::single(3));
    let got = count_with(&rt, Strategy::Hybrid, &eps, &stream);
    for (i, ep) in eps.iter().enumerate() {
        let want = serial::count_a1_bounded(ep, &stream, rt.manifest().k_slots);
        assert_eq!(got[i], want, "{}", ep.display());
    }
}

#[test]
fn empty_and_single_event_streams() {
    let Some(rt) = open_rt() else { return };
    let empty = EventStream::new(4);
    let eps = vec![Episode::new(vec![0, 1], vec![Interval::new(0, 5)])];
    let got = exec::count_a1(&rt, &eps, &empty).unwrap();
    assert_eq!(got, vec![0]);
    let single = EventStream::from_pairs(vec![(0, 5)], 4);
    let got = exec::count_a1(&rt, &eps, &single).unwrap();
    assert_eq!(got, vec![0]);
}
