//! Integration tests over the PJRT runtime: the accelerated counting path
//! (AOT Pallas kernels) against the CPU references, over every artifact.
//!
//! Requires `make artifacts` plus real PJRT bindings. When the runtime is
//! unavailable (no artifacts, or the stub `xla` crate is linked) every
//! test skips with a notice rather than failing — the CPU-path coverage
//! lives in `miner_e2e.rs` / `session_api.rs` and always runs.

#![allow(deprecated)]

use episodes_gpu::coordinator::{Coordinator, Strategy};
use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::EventStream;
use episodes_gpu::mining::serial;
use episodes_gpu::runtime::{exec, Runtime};
use episodes_gpu::util::rng::Rng;

fn open_rt() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn open_coord() -> Option<Coordinator> {
    match Coordinator::open_default() {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn gen_stream(rng: &mut Rng, n_events: usize, n_types: i32) -> EventStream {
    let mut pairs = Vec::with_capacity(n_events);
    let mut t = 0;
    for _ in 0..n_events {
        t += rng.range_i32(0, 4);
        pairs.push((rng.range_i32(0, n_types - 1), t));
    }
    EventStream::from_pairs(pairs, n_types as usize)
}

fn gen_episodes(rng: &mut Rng, count: usize, n: usize, n_types: i32) -> Vec<Episode> {
    (0..count)
        .map(|_| {
            let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, n_types - 1)).collect();
            let ivs: Vec<Interval> = (0..n - 1)
                .map(|_| {
                    let lo = rng.range_i32(0, 3);
                    Interval::new(lo, lo + rng.range_i32(1, 10))
                })
                .collect();
            Episode::new(types, ivs)
        })
        .collect()
}

#[test]
fn a1_artifacts_match_cpu_reference_all_sizes() {
    let Some(rt) = open_rt() else { return };
    let k = rt.manifest().k_slots;
    let mut rng = Rng::new(0xA1);
    let stream = gen_stream(&mut rng, 3000, 8);
    for n in rt.manifest().n_min..=rt.manifest().n_max {
        let eps = gen_episodes(&mut rng, 40, n, 8);
        let got = exec::count_a1(&rt, &eps, &stream).unwrap();
        for (i, ep) in eps.iter().enumerate() {
            let want = serial::count_a1_bounded(ep, &stream, k);
            assert_eq!(got[i], want, "n={n} ep {}", ep.display());
        }
    }
}

#[test]
fn a2_artifacts_match_cpu_reference_all_sizes() {
    let Some(rt) = open_rt() else { return };
    let mut rng = Rng::new(0xA2);
    let stream = gen_stream(&mut rng, 3000, 8);
    for n in rt.manifest().n_min..=rt.manifest().n_max {
        let eps = gen_episodes(&mut rng, 40, n, 8);
        let got = exec::count_a2(&rt, &eps, &stream).unwrap();
        for (i, ep) in eps.iter().enumerate() {
            let want = serial::count_a2(ep, &stream);
            assert_eq!(got[i], want, "n={n} ep {}", ep.display());
        }
    }
}

#[test]
fn chunk_carry_spans_multiple_chunks() {
    // stream longer than one chunk: counts must match the single-pass CPU
    // reference exactly (state carried across chunk boundaries)
    let Some(rt) = open_rt() else { return };
    let c = rt.manifest().c_chunk;
    let k = rt.manifest().k_slots;
    let mut rng = Rng::new(0xCC);
    let stream = gen_stream(&mut rng, 3 * c + 17, 6);
    let eps = gen_episodes(&mut rng, 16, 3, 6);
    let got = exec::count_a1(&rt, &eps, &stream).unwrap();
    for (i, ep) in eps.iter().enumerate() {
        assert_eq!(got[i], serial::count_a1_bounded(ep, &stream, k), "{}", ep.display());
    }
}

#[test]
fn batching_pads_beyond_m_episodes() {
    let Some(rt) = open_rt() else { return };
    let m = rt.manifest().m_episodes;
    let mut rng = Rng::new(0xBB);
    let stream = gen_stream(&mut rng, 1000, 5);
    let eps = gen_episodes(&mut rng, m + 37, 2, 5);
    let got = exec::count_a2(&rt, &eps, &stream).unwrap();
    assert_eq!(got.len(), eps.len());
    for (i, ep) in eps.iter().enumerate() {
        assert_eq!(got[i], serial::count_a2(ep, &stream), "{}", ep.display());
    }
}

#[test]
fn mapconcat_kernel_equals_cpu_map_and_serial_count() {
    let Some(rt) = open_rt() else { return };
    let mf = *rt.manifest();
    let mut rng = Rng::new(0x3C);
    let stream = gen_stream(&mut rng, 20_000, 6);
    let eps = gen_episodes(&mut rng, 8, 3, 6);
    let t0 = stream.t_begin() - 1;
    let t1 = stream.t_end();
    let span = (t1 - t0) as i64;
    let p = mf.mc_segments as i64;
    let taus: Vec<i32> =
        (0..p).map(|i| (t0 as i64 + span * i / p) as i32).chain([t1]).collect();

    let got = exec::mapcat_map(&rt, &eps, &stream, &taus).unwrap();
    for (j, ep) in eps.iter().enumerate() {
        // kernel Map == CPU Map, tuple for tuple
        let want = serial::mapcat_map(ep, &stream, &taus, mf.k_slots);
        let got_t: Vec<Vec<(i32, u64, i32)>> = got[j].clone();
        assert_eq!(got_t, want, "episode {}", ep.display());
    }
}

#[test]
fn coordinator_strategies_agree() {
    let Some(mut coord) = open_coord() else { return };
    let mut rng = Rng::new(0x57);
    let stream = gen_stream(&mut rng, 8000, 6);
    let eps = gen_episodes(&mut rng, 24, 3, 6);
    let cpu = coord.count(&eps, &stream, Strategy::CpuSerial).unwrap();
    let ptpe = coord.count(&eps, &stream, Strategy::PtpeA1).unwrap();
    let hybrid = coord.count(&eps, &stream, Strategy::Hybrid).unwrap();
    let par = coord.count(&eps, &stream, Strategy::CpuParallel).unwrap();
    assert_eq!(cpu, ptpe);
    assert_eq!(cpu, hybrid);
    assert_eq!(cpu, par);
}

#[test]
fn coordinator_mapconcat_agrees_or_falls_back() {
    let Some(mut coord) = open_coord() else { return };
    let mut rng = Rng::new(0x58);
    let stream = gen_stream(&mut rng, 30_000, 6);
    let eps = gen_episodes(&mut rng, 8, 4, 6);
    let cpu = coord.count(&eps, &stream, Strategy::CpuSerial).unwrap();
    let mc = coord.count(&eps, &stream, Strategy::MapConcat).unwrap();
    assert_eq!(cpu, mc, "metrics: {}", coord.metrics.report());
}

#[test]
fn two_pass_is_exact_at_threshold() {
    let Some(mut coord) = open_coord() else { return };
    let mut rng = Rng::new(0x2B);
    let stream = gen_stream(&mut rng, 6000, 5);
    let eps = gen_episodes(&mut rng, 64, 3, 5);
    let theta = 10;
    let out = coord.count_two_pass(&eps, &stream, theta).unwrap();
    for (i, ep) in eps.iter().enumerate() {
        let exact = serial::count_a1_bounded(ep, &stream, coord.rt.manifest().k_slots);
        // frequency decision must be exact
        assert_eq!(out.counts[i] >= theta, exact >= theta, "{}", ep.display());
        // survivors carry exact counts
        if out.relaxed_counts[i] >= theta {
            assert_eq!(out.counts[i], exact, "{}", ep.display());
        }
        // Theorem 5.1 on the kernel path
        assert!(out.relaxed_counts[i] >= exact);
    }
}

#[test]
fn mixed_size_batches_route_correctly() {
    let Some(mut coord) = open_coord() else { return };
    let mut rng = Rng::new(0x33);
    let stream = gen_stream(&mut rng, 4000, 5);
    let mut eps = gen_episodes(&mut rng, 10, 2, 5);
    eps.extend(gen_episodes(&mut rng, 10, 4, 5));
    eps.push(Episode::single(3));
    let got = coord.count(&eps, &stream, Strategy::Hybrid).unwrap();
    for (i, ep) in eps.iter().enumerate() {
        let want = serial::count_a1_bounded(ep, &stream, coord.rt.manifest().k_slots);
        assert_eq!(got[i], want, "{}", ep.display());
    }
}

#[test]
fn empty_and_single_event_streams() {
    let Some(rt) = open_rt() else { return };
    let empty = EventStream::new(4);
    let eps = vec![Episode::new(vec![0, 1], vec![Interval::new(0, 5)])];
    let got = exec::count_a1(&rt, &eps, &empty).unwrap();
    assert_eq!(got, vec![0]);
    let single = EventStream::from_pairs(vec![(0, 5)], 4);
    let got = exec::count_a1(&rt, &eps, &single).unwrap();
    assert_eq!(got, vec![0]);
}
