//! End-to-end mining over the datasets through the `Session` facade: the
//! miner must recover the episodes the generators embed (and nothing
//! structurally bogus), under both one-pass and two-pass counting.
//!
//! These tests pin the CPU backends explicitly so they run (and mean the
//! same thing) with or without the PJRT runtime present; the accelerated
//! path is pinned to the CPU references in `integration_runtime.rs`.

use episodes_gpu::coordinator::Strategy;
use episodes_gpu::datasets::{culture, sym26};
use episodes_gpu::Session;

#[test]
fn sym26_recovers_both_embedded_chains() {
    let cfg = sym26::Sym26Config::default();
    let stream = sym26::generate(&cfg, 7);
    let mut session = Session::builder()
        .stream(stream)
        .theta(60)
        .intervals(cfg.interval_set())
        .strategy(Strategy::CpuParallel)
        .build()
        .unwrap();
    let result = session.mine().unwrap();
    for embedded in cfg.embedded_episodes() {
        assert!(
            result.frequent.iter().any(|c| c.episode == embedded),
            "missing embedded chain {}",
            embedded.display()
        );
    }
    // the deepest frequent episode should be exactly the long chain's size
    let max_n = result.frequent.iter().map(|c| c.episode.n()).max().unwrap();
    assert_eq!(max_n, cfg.long_chain.len());
}

#[test]
fn one_pass_and_two_pass_find_the_same_frequent_sets() {
    let cfg = sym26::Sym26Config::default();
    let stream = sym26::generate(&cfg, 8);

    let mut one = Session::builder()
        .stream(stream.clone())
        .theta(80)
        .intervals(cfg.interval_set())
        .strategy(Strategy::CpuParallel)
        .one_pass()
        .max_level(4)
        .build()
        .unwrap();
    let r1 = one.mine().unwrap();

    let mut two = Session::builder()
        .stream(stream)
        .theta(80)
        .intervals(cfg.interval_set())
        .strategy(Strategy::CpuParallel)
        .max_level(4)
        .build()
        .unwrap();
    let r2 = two.mine().unwrap();
    assert!(two.metrics().a2_culled > 0, "two-pass should cull something");

    let set1: std::collections::HashSet<_> =
        r1.frequent.iter().map(|c| c.episode.clone()).collect();
    let set2: std::collections::HashSet<_> =
        r2.frequent.iter().map(|c| c.episode.clone()).collect();
    assert_eq!(set1, set2);
}

/// Mining threshold that separates embedded synfire chains from chance
/// in-burst coincidences at each culture age (see examples/culture_analysis).
fn culture_theta(day: u32) -> u64 {
    match day {
        33 => 40,
        34 => 85,
        _ => 140,
    }
}

fn culture_session(day: u32) -> Session {
    let cfg = culture::CultureConfig::day(day);
    let stream = culture::generate(&cfg, 11);
    Session::builder()
        .stream(stream)
        .theta(culture_theta(day))
        .intervals(cfg.interval_set())
        .strategy(Strategy::CpuParallel)
        .max_level(6)
        .build()
        .unwrap()
}

#[test]
fn culture_day35_mines_embedded_synfire_chains() {
    let cfg = culture::CultureConfig::day(35);
    let mut session = culture_session(35);
    let result = session.mine().unwrap();
    for c in &cfg.embedded_episodes() {
        assert!(
            result.frequent.iter().any(|x| x.episode == *c),
            "missing {}",
            c.display()
        );
    }
}

#[test]
fn mining_structure_grows_with_culture_age_section_6_5() {
    // §6.5: the same circuits strengthen as the culture matures — the
    // miner sees every embedded chain at every age, with higher counts
    // day over day.
    let mut per_day: Vec<Vec<u64>> = vec![];
    for day in [33u32, 35] {
        let cfg = culture::CultureConfig::day(day);
        let mut session = culture_session(day);
        let r = session.mine().unwrap();
        let counts: Vec<u64> = cfg
            .embedded_episodes()
            .iter()
            .map(|ep| {
                r.frequent
                    .iter()
                    .find(|c| c.episode == *ep)
                    .map(|c| c.count)
                    .unwrap_or(0)
            })
            .collect();
        per_day.push(counts);
    }
    for (i, (&c33, &c35)) in per_day[0].iter().zip(&per_day[1]).enumerate() {
        assert!(c33 > 0, "chain {i} missing on day 33");
        assert!(c35 > c33, "chain {i}: day35 {c35} !> day33 {c33}");
    }
}
