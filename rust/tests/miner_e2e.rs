//! End-to-end mining over the datasets: the miner must recover the
//! episodes the generators embed (and nothing structurally bogus), under
//! both one-pass and two-pass counting.

use episodes_gpu::coordinator::miner::{CountMode, MineConfig};
use episodes_gpu::coordinator::{Coordinator, Strategy};
use episodes_gpu::datasets::{culture, sym26};

#[test]
fn sym26_recovers_both_embedded_chains() {
    let cfg = sym26::Sym26Config::default();
    let stream = sym26::generate(&cfg, 7);
    let mut mine_cfg = MineConfig::new(60, cfg.interval_set());
    mine_cfg.mode = CountMode::TwoPass;
    let mut coord = Coordinator::open_default().unwrap();
    let result = coord.mine(&stream, &mine_cfg).unwrap();
    for embedded in cfg.embedded_episodes() {
        assert!(
            result.frequent.iter().any(|c| c.episode == embedded),
            "missing embedded chain {}",
            embedded.display()
        );
    }
    // the deepest frequent episode should be exactly the long chain's size
    let max_n = result.frequent.iter().map(|c| c.episode.n()).max().unwrap();
    assert_eq!(max_n, cfg.long_chain.len());
}

#[test]
fn one_pass_and_two_pass_find_the_same_frequent_sets() {
    let cfg = sym26::Sym26Config::default();
    let stream = sym26::generate(&cfg, 8);
    let mut coord = Coordinator::open_default().unwrap();

    let mut c1 = MineConfig::new(80, cfg.interval_set());
    c1.mode = CountMode::OnePass(Strategy::Hybrid);
    c1.max_level = 4;
    let r1 = coord.mine(&stream, &c1).unwrap();

    let mut c2 = c1.clone();
    c2.mode = CountMode::TwoPass;
    let r2 = coord.mine(&stream, &c2).unwrap();

    let set1: std::collections::HashSet<_> =
        r1.frequent.iter().map(|c| c.episode.clone()).collect();
    let set2: std::collections::HashSet<_> =
        r2.frequent.iter().map(|c| c.episode.clone()).collect();
    assert_eq!(set1, set2);
}

/// Mining threshold that separates embedded synfire chains from chance
/// in-burst coincidences at each culture age (see examples/culture_analysis).
fn culture_theta(day: u32) -> u64 {
    match day {
        33 => 40,
        34 => 85,
        _ => 140,
    }
}

#[test]
fn culture_day35_mines_embedded_synfire_chains() {
    let cfg = culture::CultureConfig::day(35);
    let stream = culture::generate(&cfg, 11);
    let mut mine_cfg = MineConfig::new(culture_theta(35), cfg.interval_set());
    mine_cfg.max_level = 6;
    let mut coord = Coordinator::open_default().unwrap();
    let result = coord.mine(&stream, &mine_cfg).unwrap();
    for c in &cfg.embedded_episodes() {
        assert!(
            result.frequent.iter().any(|x| x.episode == *c),
            "missing {}",
            c.display()
        );
    }
}

#[test]
fn mining_structure_grows_with_culture_age_section_6_5() {
    // §6.5: the same circuits strengthen as the culture matures — the
    // miner sees every embedded chain at every age, with higher counts
    // day over day.
    let mut coord = Coordinator::open_default().unwrap();
    let mut per_day: Vec<Vec<u64>> = vec![];
    for day in [33u32, 35] {
        let cfg = culture::CultureConfig::day(day);
        let stream = culture::generate(&cfg, 11);
        let mut mine_cfg = MineConfig::new(culture_theta(day), cfg.interval_set());
        mine_cfg.max_level = 6;
        let r = coord.mine(&stream, &mine_cfg).unwrap();
        let counts: Vec<u64> = cfg
            .embedded_episodes()
            .iter()
            .map(|ep| {
                r.frequent
                    .iter()
                    .find(|c| c.episode == *ep)
                    .map(|c| c.count)
                    .unwrap_or(0)
            })
            .collect();
        per_day.push(counts);
    }
    for (i, (&c33, &c35)) in per_day[0].iter().zip(&per_day[1]).enumerate() {
        assert!(c33 > 0, "chain {i} missing on day 33");
        assert!(c35 > c33, "chain {i}: day35 {c35} !> day33 {c33}");
    }
}
