//! The connectivity pipeline end to end: seeded determinism, batched ==
//! serial execution, the planted-chain significance property the whole
//! statistical apparatus exists for, and the served surface
//! (`Request::Connectivity` through `MineService`) against the direct
//! pipeline.

use std::sync::Arc;

use episodes_gpu::analysis::batch::BatchConfig;
use episodes_gpu::analysis::connectivity::{
    infer_connectivity, ConnectivityConfig, ConnectivityResult,
};
use episodes_gpu::coordinator::Strategy;
use episodes_gpu::datasets::sym26::{self, Sym26Config};
use episodes_gpu::events::EventStream;
use episodes_gpu::obs::Trace;
use episodes_gpu::serve::{
    Admitted, ConnectivityQuery, MineService, Query, Request, ServiceConfig,
};
use episodes_gpu::session::MineOptions;
use episodes_gpu::MineError;

/// A small but structured stream: the sym26 model scaled down so ten-odd
/// mines stay fast, with the background quieted and every chain link
/// firing, so the planted structure is unambiguous at this duration.
fn planted_cfg() -> Sym26Config {
    Sym26Config {
        duration_ms: 10_000,
        basal_hz: 5.0,
        trigger_hz: 3.0,
        link_prob: 1.0,
        ..Sym26Config::default()
    }
}

fn planted_stream(seed: u64) -> EventStream {
    sym26::generate(&planted_cfg(), seed)
}

fn opts(theta: u64) -> MineOptions {
    MineOptions {
        theta,
        intervals: planted_cfg().interval_set(),
        max_level: 3,
        max_candidates_per_level: 2_000_000,
        candidate_block: episodes_gpu::session::DEFAULT_CANDIDATE_BLOCK,
    }
}

fn cfg(n_surrogates: usize, seed: u64, parallelism: usize) -> ConnectivityConfig {
    ConnectivityConfig {
        n_surrogates,
        jitter: 15,
        seed,
        batch: BatchConfig {
            strategy: Strategy::CpuParallel,
            parallelism,
            ..BatchConfig::default()
        },
    }
}

fn run(stream: &EventStream, theta: u64, c: &ConnectivityConfig) -> ConnectivityResult {
    infer_connectivity(stream, &opts(theta), c, &Trace::off()).unwrap()
}

#[test]
fn same_seed_same_ranked_circuit() {
    let stream = planted_stream(11);
    let a = run(&stream, 10, &cfg(4, 42, 2));
    let b = run(&stream, 10, &cfg(4, 42, 2));
    assert_eq!(a.base.frequent, b.base.frequent);
    assert_eq!(a.report, b.report);
    assert_eq!(a.circuit, b.circuit);
    // a different surrogate seed is a different null sample
    let c = run(&stream, 10, &cfg(4, 43, 2));
    assert_ne!(
        a.report.scores.iter().map(|s| s.null_mean).collect::<Vec<_>>(),
        c.report.scores.iter().map(|s| s.null_mean).collect::<Vec<_>>(),
        "seed 43 must draw a different null"
    );
}

#[test]
fn batched_equals_serial_pipeline() {
    // the whole pipeline, not just mine_batch: surrogate generation is
    // index-keyed, so worker claim order must not leak into the result
    let stream = planted_stream(12);
    let serial = run(&stream, 10, &cfg(5, 7, 1));
    let batched = run(&stream, 10, &cfg(5, 7, 4));
    assert_eq!(serial.base.frequent, batched.base.frequent);
    assert_eq!(serial.report, batched.report);
    assert_eq!(serial.circuit, batched.circuit);
}

#[test]
fn planted_chains_rank_above_rate_background() {
    // The property the statistics exist for: the generator's embedded
    // chains survive jitter at the null's p-floor, and nothing the rate
    // background produces outranks them.
    let c = planted_cfg();
    let stream = sym26::generate(&c, 13);
    let result = run(&stream, 10, &cfg(9, 99, 4));
    let report = &result.report;
    assert!(!report.scores.is_empty());
    assert_eq!(report.n_surrogates, 9);
    let floor = report.p_floor();
    assert!((floor - 0.1).abs() < 1e-12);

    let truth = episodes_gpu::datasets::ground_truth("sym26").unwrap();
    let true_edges = truth.edges();

    // every true edge is recovered at the p-floor: ~30 planted
    // occurrences per link against a ~5 Hz background leave the null no
    // room to reach the real count
    let significant = result.circuit.significant(floor + 1e-9);
    for (from, to) in &true_edges {
        assert!(
            significant.contains(*from, *to),
            "true edge {from}->{to} missing from the p-floor set; circuit: {:?}",
            result.circuit.edges
        );
    }
    let s = significant.score(&truth.chains);
    assert_eq!(s.true_positives, true_edges.len(), "recall {:.2}", s.recall());

    // and the ranking puts them first: the top |truth| edges are exactly
    // the planted ones (rate-driven coincidences jitter away)
    for e in result.circuit.edges.iter().take(true_edges.len()) {
        assert!(
            true_edges.contains(&(e.from, e.to)),
            "non-planted edge {}->{} (p={}) outranks a planted one",
            e.from,
            e.to,
            e.p_value
        );
    }
}

fn serve_query(stream: &Arc<EventStream>, theta: u64) -> ConnectivityQuery {
    let mine = Query::new(Arc::clone(stream), theta, planted_cfg().interval_set()).max_level(3);
    ConnectivityQuery::new(mine, 4, 15, 77)
}

#[test]
fn served_connectivity_matches_direct_pipeline() {
    let stream = Arc::new(planted_stream(14));
    let service = MineService::start(ServiceConfig {
        workers: 2,
        strategy: Strategy::CpuSerial,
        connectivity_parallelism: 2,
        ..ServiceConfig::default()
    })
    .unwrap();

    let q = serve_query(&stream, 10);
    let served = match service.request(Request::Connectivity(q.clone())).unwrap() {
        Admitted::Connectivity(t) => {
            assert!(!t.from_cache());
            t.wait().unwrap()
        }
        _ => panic!("connectivity request admitted as a different kind"),
    };

    // direct pipeline under the service's effective config; the batch
    // parallelism knob is result-invariant, so any value compares equal
    let direct = infer_connectivity(
        &stream,
        &opts(10),
        &ConnectivityConfig {
            n_surrogates: q.n_surrogates,
            jitter: q.jitter,
            seed: q.seed,
            batch: BatchConfig {
                strategy: Strategy::CpuSerial,
                parallelism: 1,
                ..BatchConfig::default()
            },
        },
        &Trace::off(),
    )
    .unwrap();
    assert_eq!(served.base.frequent, direct.base.frequent);
    assert_eq!(served.report, direct.report);
    assert_eq!(served.circuit, direct.circuit);

    // one admission = one tenant job: a resubmission is a cache hit on
    // the connectivity-kind key, sharing the same Arc'd result
    let again = match service.request(Request::Connectivity(q)).unwrap() {
        Admitted::Connectivity(t) => {
            assert!(t.from_cache(), "identical resubmission must hit the cache");
            t.wait().unwrap()
        }
        _ => panic!("connectivity request admitted as a different kind"),
    };
    assert!(Arc::ptr_eq(&served, &again));

    let m = service.shutdown();
    assert_eq!(m.failed, 0);
    assert_eq!(m.completed, 1, "two requests, one execution");
}

#[test]
fn service_rejects_invalid_connectivity_at_admission() {
    let stream = Arc::new(planted_stream(15));
    let service =
        MineService::start(ServiceConfig { workers: 1, ..ServiceConfig::default() }).unwrap();

    let mut zero_surrogates = serve_query(&stream, 10);
    zero_surrogates.n_surrogates = 0;
    assert!(matches!(
        service.request(Request::Connectivity(zero_surrogates)),
        Err(MineError::InvalidConfig { .. })
    ));

    let mut zero_jitter = serve_query(&stream, 10);
    zero_jitter.jitter = 0;
    assert!(matches!(
        service.request(Request::Connectivity(zero_jitter)),
        Err(MineError::InvalidConfig { .. })
    ));

    let mut bad_mine = serve_query(&stream, 10);
    bad_mine.mine.theta = 0;
    assert!(service.request(Request::Connectivity(bad_mine)).is_err());

    let m = service.shutdown();
    assert_eq!(m.completed + m.failed, 0, "rejected requests never reach a worker");
}
