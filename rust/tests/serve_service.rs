//! Service-level behavior: result equivalence against direct `Session`
//! mining, deterministic coalescing/admission/drain via the paused pool,
//! and cache semantics.

use std::sync::Arc;

use episodes_gpu::coordinator::Strategy;
use episodes_gpu::episodes::Interval;
use episodes_gpu::events::EventStream;
use episodes_gpu::serve::loadgen::{self, LoadGenConfig, Workload};
use episodes_gpu::serve::{MineService, Query, ServiceConfig};
use episodes_gpu::{MineError, Session};

fn small_workload_cfg() -> LoadGenConfig {
    LoadGenConfig {
        clients: 4,
        requests_per_client: 12,
        base_events: 2_500,
        distinct_pool: 6,
        distinct_events: 500,
        window_ticks: 1_200,
        max_level: 3,
        ..LoadGenConfig::default()
    }
}

fn cpu_service(workers: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        strategy: Strategy::CpuSerial,
        ..ServiceConfig::default()
    }
}

fn distinct_query(seed: i32) -> Query {
    // tiny unique streams: the seed perturbs one tick, so every seed is a
    // distinct QueryKey
    let stream = Arc::new(EventStream::from_pairs(
        vec![(0, 1), (1, 3 + seed), (0, 9 + seed), (1, 14 + seed)],
        2,
    ));
    Query::new(stream, 1, vec![Interval::new(0, 8)]).max_level(2)
}

#[test]
fn service_results_match_direct_session_mining() {
    // The acceptance criterion: for every query in a mixed scenario set
    // (hot, sweep, distinct, sliding windows), the service returns counts
    // identical to a direct Session::mine.
    let workload = Workload::build(&small_workload_cfg()).unwrap();
    let service = MineService::start(cpu_service(3)).unwrap();
    for (i, q) in workload.all().enumerate() {
        let mut session = Session::builder()
            .stream((*q.stream).clone())
            .theta(q.theta)
            .intervals(q.intervals.clone())
            .max_level(q.max_level)
            .strategy(Strategy::CpuSerial)
            .build()
            .unwrap();
        let direct = session.mine().unwrap();
        let served = service.submit(q.clone()).unwrap().wait().unwrap();
        assert_eq!(served.frequent, direct.frequent, "query {i}: counts diverge");
        let shape =
            |r: &episodes_gpu::coordinator::miner::MineResult| -> Vec<(usize, usize, usize)> {
                r.levels.iter().map(|l| (l.level, l.candidates, l.frequent)).collect()
            };
        assert_eq!(shape(&served), shape(&direct), "query {i}: level shapes diverge");
    }
    let m = service.shutdown();
    assert_eq!(m.failed, 0);
}

#[test]
fn repeat_queries_hit_the_cache() {
    let service = MineService::start(cpu_service(2)).unwrap();
    let q = distinct_query(0);
    let first = service.submit(q.clone()).unwrap();
    assert!(!first.from_cache());
    let first = first.wait().unwrap();
    let second = service.submit(q).unwrap();
    assert!(second.from_cache(), "repeat must be answered from the cache");
    let second = second.wait().unwrap();
    assert_eq!(first.frequent, second.frequent);
    let m = service.shutdown();
    assert!(m.cache.hits >= 1, "{:?}", m.cache);
    assert_eq!(m.completed, 1, "one execution serves both requests");
}

#[test]
fn identical_inflight_queries_coalesce_into_one_execution() {
    // Paused pool: submissions queue but nothing executes, so the five
    // identical submissions below deterministically find the first one
    // in flight.
    let service = MineService::start_paused(cpu_service(1)).unwrap();
    let q = distinct_query(1);
    let tickets: Vec<_> =
        (0..5).map(|_| service.submit(q.clone()).unwrap()).collect();
    let m = service.metrics();
    assert_eq!(m.queue_depth, 1, "five identical submissions, one queued job");
    assert_eq!(m.coalesced, 4);
    assert_eq!(
        m.coalesced_waiting, 4,
        "waiters ride the in-flight job, they do not hold queue slots"
    );
    service.resume();
    let mut results = tickets.into_iter().map(|t| t.wait().unwrap());
    let first = results.next().unwrap();
    for r in results {
        assert!(Arc::ptr_eq(&first, &r), "coalesced waiters share one result");
    }
    let m = service.shutdown();
    assert_eq!(m.completed, 1);
    assert_eq!(m.coalesced_waiting, 0, "a resolved job releases its waiters");
}

#[test]
fn full_queue_rejects_with_typed_busy() {
    let service = MineService::start_paused(ServiceConfig {
        queue_capacity: 2,
        ..cpu_service(1)
    })
    .unwrap();
    let t1 = service.submit(distinct_query(2)).unwrap();
    let t2 = service.submit(distinct_query(3)).unwrap();
    let err = service.submit(distinct_query(4)).err().unwrap();
    assert!(
        matches!(err, MineError::Busy { queue_depth: 2, capacity: 2 }),
        "{err}"
    );
    let m = service.metrics();
    assert_eq!(m.rejected, 1);
    service.resume();
    assert!(t1.wait().is_ok() && t2.wait().is_ok());
    service.shutdown();
}

#[test]
fn shutdown_drains_queued_jobs() {
    // Even a never-resumed pool must answer every admitted ticket on
    // shutdown (drain, not abandon).
    let service = MineService::start_paused(cpu_service(2)).unwrap();
    let tickets: Vec<_> =
        (0..3).map(|i| service.submit(distinct_query(10 + i)).unwrap()).collect();
    let m = service.shutdown();
    assert_eq!(m.completed, 3, "drain executes all queued jobs");
    for t in tickets {
        assert!(t.wait().is_ok());
    }
}

#[test]
fn invalid_queries_are_rejected_at_admission() {
    let service = MineService::start(cpu_service(1)).unwrap();
    let mut q = distinct_query(5);
    q.theta = 0;
    let err = service.submit(q).err().unwrap();
    assert!(matches!(err, MineError::InvalidConfig { .. }), "{err}");
    let m = service.shutdown();
    assert_eq!(m.submitted, 0, "validation failures never count as admitted");
}

#[test]
fn loadgen_closed_loop_accounts_for_every_request() {
    let cfg = small_workload_cfg();
    let workload = Workload::build(&cfg).unwrap();
    let service = MineService::start(cpu_service(3)).unwrap();
    let report = loadgen::run(&service, &workload, &cfg);
    let issued = (cfg.clients * cfg.requests_per_client) as u64;
    assert_eq!(report.completed + report.rejected + report.errors, issued);
    assert_eq!(report.errors, 0, "no query in the scenario set may error");
    assert!(report.latency_ns.is_some());
    let json = report.to_json();
    assert!(json.contains("\"qps\":") && json.contains("\"p99\":"), "{json}");
    let m = service.shutdown();
    assert_eq!(m.worker_busy.len(), 3);
    assert!(m.cache.hits + m.cache.misses > 0);
}
