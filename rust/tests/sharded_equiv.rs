//! Backend-equivalence suite for the stream-sharded CPU engine (the
//! randomized tests CI runs with `--release`; see `.github/workflows`).
//!
//! The contract under test: [`ShardedBackend`] splits the stream into
//! per-thread time shards, maps boundary machines per shard, stitches with
//! the Concatenate fold, and recounts flagged misses serially — so its
//! counts must equal the serial reference *exactly*, for every shard
//! count, on both the unbounded (default) and bounded-K configurations,
//! and the frequency decision must survive `TwoPassBackend` composition.

use episodes_gpu::backend::sharded::ShardedBackend;
use episodes_gpu::backend::two_pass::TwoPassBackend;
use episodes_gpu::backend::CountBackend;
use episodes_gpu::coordinator::mapconcat::{concatenate_fold, concatenate_tree};
use episodes_gpu::episodes::{Episode, Interval};
use episodes_gpu::events::{EventStream, Tick};
use episodes_gpu::mining::serial;
use episodes_gpu::util::prop::{forall, small_size};
use episodes_gpu::util::rng::Rng;

const SHARD_COUNTS: [usize; 5] = [1, 2, 4, 8, 16];

fn gen_stream(rng: &mut Rng, n_events: usize, n_types: i32) -> EventStream {
    let mut pairs = Vec::with_capacity(n_events);
    let mut t = 0;
    for _ in 0..n_events {
        t += rng.range_i32(0, 3);
        pairs.push((rng.range_i32(0, n_types - 1), t));
    }
    EventStream::from_pairs(pairs, n_types as usize)
}

fn gen_episode(rng: &mut Rng, n_types: i32) -> Episode {
    let n = rng.range_i32(2, 4) as usize;
    let types: Vec<i32> = (0..n).map(|_| rng.range_i32(0, n_types - 1)).collect();
    let ivs: Vec<Interval> = (0..n - 1)
        .map(|_| {
            let lo = rng.range_i32(0, 2);
            Interval::new(lo, lo + rng.range_i32(1, 8))
        })
        .collect();
    Episode::new(types, ivs)
}

#[test]
fn sharded_equals_serial_across_shard_counts() {
    for seed in 0..6 {
        let mut rng = Rng::new(seed);
        let stream = gen_stream(&mut rng, 1500, 5);
        let mut eps: Vec<Episode> = (0..10).map(|_| gen_episode(&mut rng, 5)).collect();
        eps.push(Episode::single(2)); // mixed batch: n=1 rides the host path
        let want: Vec<u64> =
            eps.iter().map(|e| serial::count_a1(e, &stream)).collect();
        for shards in SHARD_COUNTS {
            let rep = ShardedBackend::new(shards).count(&eps, &stream).unwrap();
            assert_eq!(rep.counts, want, "seed {seed} shards {shards}");
        }
    }
}

#[test]
fn sharded_bounded_equals_bounded_serial_across_shard_counts() {
    // bounded-K configuration: equivalence target is the kernel-semantics
    // count_a1_bounded at the same K (miss-recount path uses it too)
    for seed in 100..104 {
        let mut rng = Rng::new(seed);
        let stream = gen_stream(&mut rng, 1200, 4);
        let eps: Vec<Episode> = (0..8).map(|_| gen_episode(&mut rng, 4)).collect();
        for k in [1, 2, 8] {
            let want: Vec<u64> =
                eps.iter().map(|e| serial::count_a1_bounded(e, &stream, k)).collect();
            for shards in SHARD_COUNTS {
                let rep =
                    ShardedBackend::new(shards).with_k(k).count(&eps, &stream).unwrap();
                assert_eq!(rep.counts, want, "seed {seed} k {k} shards {shards}");
            }
        }
    }
}

#[test]
fn prop_sharded_equals_serial_on_random_worlds() {
    // randomized streams *and* randomized shard counts, including shard
    // counts the planner must reject (stream too short → episode-axis
    // fallback) — counts are exact either way
    forall("sharded == serial", 0x51A2, 60, |rng| {
        let stream = gen_stream(rng, 40 + small_size(rng, 1200), 5);
        let eps: Vec<Episode> =
            (0..1 + small_size(rng, 8)).map(|_| gen_episode(rng, 5)).collect();
        let shards = 1 + rng.below(16) as usize;
        let got = ShardedBackend::new(shards).count(&eps, &stream).unwrap().counts;
        for (i, ep) in eps.iter().enumerate() {
            let want = serial::count_a1(ep, &stream);
            if got[i] != want {
                return Err(format!(
                    "{}: shards={shards} sharded={} serial={want}",
                    ep.display(),
                    got[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_two_pass_is_exact_at_threshold() {
    // mirror of `two_pass_is_exact_at_threshold` with the sharded engine
    // inside: the `count >= theta` decision of the composition must equal
    // the serial reference on every randomized world
    forall("two-pass(cpu-sharded) decision == serial", 0x2B5D, 30, |rng| {
        let stream = gen_stream(rng, 800, 5);
        let eps: Vec<Episode> = (0..20).map(|_| gen_episode(rng, 5)).collect();
        let theta = 4u64;
        let shards = 1 + rng.below(8) as usize;
        let mut tp = TwoPassBackend::new(Box::new(ShardedBackend::new(shards)), theta);
        let (out, _) = tp.run(&eps, &stream).map_err(|e| e.to_string())?;
        for (i, ep) in eps.iter().enumerate() {
            let exact = serial::count_a1(ep, &stream);
            if (out.counts[i] >= theta) != (exact >= theta) {
                return Err(format!(
                    "{}: shards={shards} decision {} vs exact {exact} (theta {theta})",
                    ep.display(),
                    out.counts[i]
                ));
            }
            if out.relaxed_counts[i] >= theta && out.counts[i] != exact {
                return Err(format!(
                    "{}: survivor count {} != exact {exact}",
                    ep.display(),
                    out.counts[i]
                ));
            }
            if out.relaxed_counts[i] < exact {
                return Err(format!(
                    "{}: relaxed {} < exact {exact} (Theorem 5.1)",
                    ep.display(),
                    out.relaxed_counts[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn concatenate_fold_single_segment_is_machine_zero() {
    let seg: Vec<Vec<(Tick, u64, Tick)>> = vec![vec![(0, 3, 17), (5, 1, 9)]];
    assert_eq!(concatenate_fold(&seg), (3, 0));
    assert_eq!(concatenate_tree(&seg), (3, 0));
}

#[test]
fn concatenate_fold_all_miss_accumulates_machine_zero() {
    // no b == a match anywhere: every chain step is a flagged miss and the
    // fold falls back to machine 0 of each segment
    let segs: Vec<Vec<(Tick, u64, Tick)>> =
        vec![vec![(0, 2, 10)], vec![(99, 3, 20)], vec![(77, 4, 30)]];
    assert_eq!(concatenate_fold(&segs), (9, 2));
}

#[test]
fn concatenate_fold_empty_inputs_do_not_panic() {
    let empty: Vec<Vec<(Tick, u64, Tick)>> = vec![];
    assert_eq!(concatenate_fold(&empty), (0, 0));
    assert_eq!(concatenate_tree(&empty), (0, 0));
    // a hollow first segment cannot anchor the chain: the count is 0 but
    // every step is flagged as a miss so miss-recounting callers never
    // trust it as exact
    let hollow: Vec<Vec<(Tick, u64, Tick)>> = vec![vec![], vec![(5, 3, 9)]];
    assert_eq!(concatenate_fold(&hollow), (0, 2));
}
