//! The bench harness contract: registry completeness, schema round-trip
//! through real files, `--check` verdict logic (including the acceptance
//! criterion that an artificially tightened baseline demonstrably fails),
//! and one registered suite run end-to-end through the shared measurement
//! loop.

use std::path::PathBuf;

use episodes_gpu::bench::{
    check_suite, find, run_suite, CheckConfig, SuiteResult, Verdict, SCHEMA_VERSION, SUITES,
};

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("bench_harness_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn registry_covers_every_bench_target() {
    // the Cargo [[bench]] targets, which must stay in lockstep with the
    // registry (each bench main is a thin registrant over its suite)
    let expected = [
        "fig7_algorithms",
        "fig9_twopass",
        "fig10_profiler",
        "fig11_gpu_cpu",
        "table1_crossover",
        "perf_kernels",
        "ablation_k_slots",
        "axis_scaling",
        "serve_load",
        "ingest_replay",
        "stream_incremental",
        "candidate_scaling",
        "cluster_scatter",
        "connectivity",
    ];
    assert_eq!(SUITES.len(), expected.len());
    for name in expected {
        let def = find(name).unwrap_or_else(|| panic!("suite {name} not registered"));
        assert!(!def.description.is_empty());
    }
}

#[test]
fn smoke_run_round_trips_and_self_checks() {
    // axis_scaling is pure CPU and cheap in smoke mode: the end-to-end
    // proof that a registered scenario flows measurement -> schema ->
    // file -> parse -> check
    let def = find("axis_scaling").unwrap();
    let result = run_suite(def, true).expect("axis_scaling smoke run");

    assert_eq!(result.schema_version, SCHEMA_VERSION);
    assert_eq!(result.suite, "axis_scaling");
    assert!(result.env.smoke);
    assert!(!result.scenarios.is_empty());
    let mut names: Vec<&str> = result.scenarios.iter().map(|s| s.name.as_str()).collect();
    assert!(names.contains(&"threads1/episode_axis"), "{names:?}");
    assert!(names.contains(&"threads1/stream_axis"), "{names:?}");
    let n_before = names.len();
    names.sort();
    names.dedup();
    assert_eq!(names.len(), n_before, "scenario names must be unique");
    for s in &result.scenarios {
        assert!(s.median_ns > 0.0, "{}: empty measurement", s.name);
        assert!(s.iters >= 1);
        assert!(s.events_per_s.unwrap() > 0.0, "{}: counting work declared", s.name);
        assert_eq!(s.item_unit.as_deref(), Some("episodes"));
    }

    // file round-trip
    let dir = scratch("roundtrip");
    let path = dir.join("BENCH_axis_scaling.json");
    std::fs::write(&path, result.to_json()).unwrap();
    let back = SuiteResult::from_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(back, result);

    // a fresh run checked against itself is within noise
    let report = check_suite(&result, &back, &CheckConfig::default());
    assert!(report.passed(), "{}", report.render());

    // ...and an artificially tightened baseline demonstrably fails
    let mut tightened = back.clone();
    for s in &mut tightened.scenarios {
        s.median_ns /= 100.0;
        s.tolerance = Some(1.0);
    }
    let report = check_suite(&result, &tightened, &CheckConfig::default());
    assert!(!report.passed(), "tightened baseline must fail:\n{}", report.render());
    assert!(report.regressions() >= 1);
    assert!(
        report.entries.iter().any(|e| e.verdict == Verdict::Regression),
        "{}",
        report.render()
    );

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn committed_baselines_parse_and_match_registry() {
    // every committed baseline must stay schema-valid, claim the suite it
    // is named for, and use the smoke/release profile CI checks against
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("benches/baselines");
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).expect("benches/baselines directory") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let stem = path.file_stem().unwrap().to_str().unwrap().to_string();
        let text = std::fs::read_to_string(&path).unwrap();
        let baseline = SuiteResult::from_json(&text)
            .unwrap_or_else(|e| panic!("baseline {stem}: {e}"));
        assert_eq!(baseline.suite, stem, "baseline file name must match its suite");
        assert!(find(&baseline.suite).is_some(), "baseline {stem} names unknown suite");
        assert!(baseline.env.smoke, "committed baselines gate the --smoke profile");
        assert_eq!(baseline.env.profile, "release");
        for s in &baseline.scenarios {
            assert!(s.median_ns > 0.0, "{stem}/{}", s.name);
            assert!(
                s.tolerance.is_some(),
                "{stem}/{}: committed baselines carry explicit tolerances",
                s.name
            );
        }
        found += 1;
    }
    assert_eq!(found, SUITES.len(), "one committed baseline per registered suite");
}

#[test]
fn check_refuses_profile_mismatch() {
    let def = find("axis_scaling").unwrap();
    let current = run_suite(def, true).unwrap();
    let mut full_baseline = current.clone();
    full_baseline.env.smoke = false;
    let report = check_suite(&current, &full_baseline, &CheckConfig::default());
    assert!(!report.passed());
    assert!(report.render().contains("NOT COMPARABLE"), "{}", report.render());
}
