//! Chip-on-chip streaming (paper §1 contribution 3): one chip (the MEA)
//! supplies the spike train, the other mines it in near real time,
//! partition by partition.
//!
//! A producer thread replays a Sym26 recording at a configurable speedup
//! into a bounded channel; a `Session` mines each partition as it arrives
//! via `mine_partitions`. The real-time criterion the paper claims is that
//! mining a partition finishes before the next partition's worth of
//! recording has been produced — reported below as per-partition latency
//! vs recording time. Note the producer pacing rules: at `--speedup 1`
//! (real time) sleeps are honored exactly, while accelerated replays cap
//! per-partition sleeps so the bench finishes quickly.
//!
//! Run: `cargo run --release --example streaming_realtime \
//!       [-- --width-ms 10000 --speedup 50 --theta 12 --channel-bound 4]`

use episodes_gpu::coordinator::streaming::{spawn_producer_with, ProducerConfig};
use episodes_gpu::datasets::sym26::{generate, Sym26Config};
use episodes_gpu::util::benchkit::Table;
use episodes_gpu::util::cli::Args;
use episodes_gpu::{MineError, Session};

fn main() -> Result<(), MineError> {
    let args = Args::from_env();
    let width_ms = args.get_i32("width-ms", 10_000)?;
    let speedup = args.get_f64("speedup", 50.0)?;
    // per-partition threshold: scale the full-recording theta by the
    // partition fraction
    let theta = args.get_u64("theta", 12)?;
    let channel_bound = args.get_usize("channel-bound", 4)?;

    let cfg = Sym26Config::default();
    let stream = generate(&cfg, 21);
    let n_parts = (stream.span() / width_ms) as usize + 1;
    println!(
        "streaming {} events over {} partitions of {width_ms} ms (replay {speedup}x, \
         channel bound {channel_bound})",
        stream.len(),
        n_parts
    );

    let mut session = Session::builder()
        .stream(stream.clone())
        .theta(theta)
        .intervals(cfg.interval_set())
        .max_level(6)
        .build()?;
    println!("backend: {}", session.backend_name());

    // Warm the backend before the MEA "starts": count batches of every
    // size the partition miner will reach (2..=max_level), once as a
    // large batch (PTPE dispatch arm) and once as a singleton
    // (MapConcatenate arm), so all one-time artifact compilation happens
    // here and the first partition's latency measures mining, not setup
    // (the real deployment compiles at boot). The session counts
    // two-pass, so warm-up episodes must *survive* the relaxed A2 cull to
    // reach the exact A1/mapcat kernels: prefixes of the embedded long
    // chain do (the generator fires them ~2 Hz, far above theta), where
    // random type chains would be culled after the A2 pass and leave the
    // exact kernels cold.
    let iv = cfg.interval_set()[0];
    for n in 2..=6usize {
        let prefix = episodes_gpu::episodes::Episode::new(
            cfg.long_chain[..n].to_vec(),
            vec![iv; n - 1],
        );
        let batch = vec![prefix.clone(); 64];
        session.count(&batch)?;
        session.count(std::slice::from_ref(&prefix))?;
    }

    let rx = spawn_producer_with(
        stream,
        width_ms,
        ProducerConfig { speedup, channel_bound, ..Default::default() },
    )?;
    let reports = session.mine_partitions(rx)?;

    let mut table = Table::new(
        "Per-partition mining latency (real-time criterion: latency <= recording)",
        &["part", "events", "frequent", "latency", "recording", "rt-ok"],
    );
    let mut all_ok = true;
    for r in &reports {
        all_ok &= r.realtime_ok();
        table.row(vec![
            r.index.to_string(),
            r.events.to_string(),
            r.frequent.to_string(),
            format!("{:.0}ms", r.mine_time.as_secs_f64() * 1e3),
            format!("{:.0}ms", r.recording.as_secs_f64() * 1e3),
            if r.realtime_ok() { "yes".into() } else { "NO".into() },
        ]);
    }
    table.print();

    let worst = reports
        .iter()
        .map(|r| r.mine_time.as_secs_f64() / r.recording.as_secs_f64())
        .fold(0.0f64, f64::max);
    println!(
        "\nworst partition latency = {:.1}% of recording time -> \
         sustainable real-time headroom {:.1}x",
        worst * 100.0,
        1.0 / worst.max(1e-9)
    );
    println!("streaming_realtime OK (all partitions real-time: {all_ok})");
    Ok(())
}
