//! Chip-on-chip streaming (paper §1 contribution 3): one chip (the MEA)
//! supplies the spike train, the other mines it in near real time,
//! partition by partition.
//!
//! A producer thread replays a Sym26 recording at a configurable speedup
//! into a bounded channel; the coordinator mines each partition as it
//! arrives. The real-time criterion the paper claims is that mining a
//! partition finishes before the next partition's worth of recording has
//! been produced — reported below as per-partition latency vs recording
//! time.
//!
//! Run: `make artifacts && cargo run --release --example streaming_realtime \
//!       [-- --width-ms 10000 --speedup 50 --theta 12]`

use episodes_gpu::coordinator::miner::{CountMode, MineConfig};
use episodes_gpu::coordinator::streaming::spawn_producer;
use episodes_gpu::coordinator::Coordinator;
use episodes_gpu::datasets::sym26::{generate, Sym26Config};
use episodes_gpu::util::benchkit::Table;
use episodes_gpu::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let width_ms = args.get_i32("width-ms", 10_000);
    let speedup = args.get_f64("speedup", 50.0);
    // per-partition threshold: scale the full-recording theta by the
    // partition fraction
    let theta = args.get_u64("theta", 12);

    let cfg = Sym26Config::default();
    let stream = generate(&cfg, 21);
    let n_parts = (stream.span() / width_ms) as usize + 1;
    println!(
        "streaming {} events over {} partitions of {width_ms} ms (replay {speedup}x)",
        stream.len(),
        n_parts
    );

    let mut coord = Coordinator::open_default()?;
    // Pre-compile the artifacts the partition miner will need, so the
    // first partition's latency is not dominated by one-time compilation
    // (the real deployment compiles at boot, before the MEA starts).
    for n in 2..=6 {
        coord.rt.executable(&format!("a2_n{n}"))?;
        coord.rt.executable(&format!("a1_n{n}"))?;
        coord.rt.executable(&format!("mapcat_n{n}"))?;
    }

    let mut mine_cfg = MineConfig::new(theta, cfg.interval_set());
    mine_cfg.mode = CountMode::TwoPass;
    mine_cfg.max_level = 6;

    let rx = spawn_producer(stream, width_ms, speedup);
    let reports = coord.mine_stream(rx, &mine_cfg)?;

    let mut table = Table::new(
        "Per-partition mining latency (real-time criterion: latency <= recording)",
        &["part", "events", "frequent", "latency", "recording", "rt-ok"],
    );
    let mut all_ok = true;
    for r in &reports {
        all_ok &= r.realtime_ok();
        table.row(vec![
            r.index.to_string(),
            r.events.to_string(),
            r.frequent.to_string(),
            format!("{:.0}ms", r.mine_time.as_secs_f64() * 1e3),
            format!("{:.0}ms", r.recording.as_secs_f64() * 1e3),
            if r.realtime_ok() { "yes".into() } else { "NO".into() },
        ]);
    }
    table.print();

    let worst = reports
        .iter()
        .map(|r| r.mine_time.as_secs_f64() / r.recording.as_secs_f64())
        .fold(0.0f64, f64::max);
    println!(
        "\nworst partition latency = {:.1}% of recording time -> \
         sustainable real-time headroom {:.1}x",
        worst * 100.0,
        1.0 / worst.max(1e-9)
    );
    println!("streaming_realtime OK (all partitions real-time: {all_ok})");
    Ok(())
}
