//! Mining evolving neuronal cultures (paper §6.5).
//!
//! Mines simulated developing-culture recordings (the 2-1-33/34/35
//! analogs) day by day — one `Session` per day's recording — and reports
//! how the set of frequent episodes (the proxy for reconstructed
//! functional circuitry) grows as the culture matures, the phenomenon the
//! paper's supplementary videos show.
//!
//! Run: `cargo run --release --example culture_analysis`

use episodes_gpu::datasets::culture::{generate, CultureConfig};
use episodes_gpu::util::benchkit::Table;
use episodes_gpu::{MineError, Session};

/// One day's mining session at that age's chance-separating threshold
/// (chance pair counts grow with burst density; DESIGN.md §5 sub. 2).
fn day_session(day: u32, seed: u64) -> Result<(CultureConfig, Session), MineError> {
    let cfg = CultureConfig::day(day);
    let stream = generate(&cfg, seed);
    let theta = match day {
        33 => 40,
        34 => 85,
        _ => 140,
    };
    let session = Session::builder()
        .stream(stream)
        .theta(theta)
        .intervals(cfg.interval_set())
        .max_level(6)
        .build()?;
    Ok((cfg, session))
}

fn main() -> Result<(), MineError> {
    let mut table = Table::new(
        "Culture development (simulated Wagenaar 2-1 analogs)",
        &["day", "events", "bursts/s", "freq-2", "freq-3", "freq>=4", "deepest", "mine-s"],
    );

    let mut per_day: Vec<(u32, Vec<String>)> = vec![];
    for day in [33u32, 34, 35] {
        let (cfg, mut session) = day_session(day, 11)?;
        let n_events = session.stream().len();

        let t0 = std::time::Instant::now();
        let result = session.mine()?;
        let secs = t0.elapsed().as_secs_f64();

        let f2 = result.frequent.iter().filter(|c| c.episode.n() == 2).count();
        let f3 = result.frequent.iter().filter(|c| c.episode.n() == 3).count();
        let f4p = result.frequent.iter().filter(|c| c.episode.n() >= 4).count();
        let deepest = result.frequent.iter().map(|c| c.episode.n()).max().unwrap_or(0);
        table.row(vec![
            format!("2-1-{day}"),
            n_events.to_string(),
            format!("{:.2}", cfg.burst_hz),
            f2.to_string(),
            f3.to_string(),
            f4p.to_string(),
            deepest.to_string(),
            format!("{secs:.2}"),
        ]);

        // chains the simulator embeds that were recovered today
        let mut recovered = vec![];
        for ep in cfg.embedded_episodes() {
            if let Some(c) = result.frequent.iter().find(|c| c.episode == ep) {
                recovered.push(format!("  [{:>3}x] {}", c.count, ep.display()));
            }
        }
        per_day.push((day, recovered));
    }

    table.print();
    println!("\nembedded synfire chains recovered per day:");
    for (day, recovered) in &per_day {
        println!("day {day}:");
        for line in recovered {
            println!("{line}");
        }
    }

    // circuit reconstruction on the final day (paper Fig. 1: episodes ->
    // functional connectivity), scored against the generator ground truth
    let (cfg, mut session) = day_session(35, 11)?;
    let result = session.mine()?;
    let deep: Vec<_> =
        result.frequent.iter().filter(|c| c.episode.n() >= 2).cloned().collect();
    let circuit = episodes_gpu::analysis::connectivity::Circuit::reconstruct(&deep);
    let score = circuit.score(&cfg.embedded_episodes());
    println!(
        "\nday-35 circuit reconstruction: {} edges, precision {:.2}, recall {:.2}, F1 {:.2}",
        circuit.edges.len(),
        score.precision(),
        score.recall(),
        score.f1()
    );
    println!("\nculture_analysis OK — structure grows with culture age (§6.5)");
    Ok(())
}
