//! Quickstart: the end-to-end driver (DESIGN.md deliverable b).
//!
//! Generates the paper's Sym26 synthetic dataset (26 neurons, 20 Hz basal
//! Poisson, two embedded causal chains), runs the full level-wise two-pass
//! (A2+A1) mining pipeline through the `Session` facade, and checks that
//! the embedded chains are recovered. The session picks the accelerated
//! Hybrid backend when the PJRT runtime and artifacts are present
//! (`make artifacts`) and the multithreaded CPU baseline otherwise — the
//! workload of paper §6.2 at one support threshold either way.
//!
//! Run: `cargo run --release --example quickstart`

use episodes_gpu::datasets::sym26::{generate, Sym26Config};
use episodes_gpu::{MineError, Session};

fn main() -> Result<(), MineError> {
    let cfg = Sym26Config::default();
    let stream = generate(&cfg, 7);
    println!(
        "Sym26: {} events / {} neurons / {:.0}s  (paper §6.1.1: ~50k events, 60s)",
        stream.len(),
        stream.n_types,
        stream.span() as f64 / 1000.0
    );

    let theta = 60;
    let mut session = Session::builder()
        .stream(stream)
        .theta(theta)
        .intervals(cfg.interval_set())
        .build()?;
    println!("backend: {}\n", session.backend_name());

    let t0 = std::time::Instant::now();
    let result = session.mine()?;
    let total = t0.elapsed();

    println!("level  candidates  frequent  a2-culled  count-time");
    for l in &result.levels {
        println!(
            "{:>5}  {:>10}  {:>8}  {:>9}  {:>9.3}s",
            l.level, l.candidates, l.frequent, l.culled_by_a2, l.count_seconds
        );
    }
    println!("\ntotal wall time: {:.2}s", total.as_secs_f64());
    println!("session metrics: {}\n", session.metrics().report());

    // verify the generator's ground truth was recovered
    let mut ok = true;
    for embedded in cfg.embedded_episodes() {
        let found = result.frequent.iter().find(|c| c.episode == embedded);
        match found {
            Some(c) => println!("recovered [{}x] {}", c.count, c.episode.display()),
            None => {
                ok = false;
                println!("MISSING embedded chain {}", embedded.display());
            }
        }
    }
    if !ok {
        return Err(MineError::internal("embedded chains not recovered"));
    }
    println!("\nquickstart OK");
    Ok(())
}
